//! Runtime-dispatched explicit-SIMD kernels: one [`Kernels`] table of
//! plain `fn` pointers per instruction set, selected once at engine load
//! (`--simd auto|scalar|neon|avx2`) and resolved ONCE per matrix pass by
//! the matvec/matmat kernels and the engine's streaming `RowView` — the
//! unified kernel surface that replaced the per-call dtype matching and
//! the scalar/`_par` twin functions.
//!
//! # Dispatch rules
//!
//! * `auto` (the default) picks the best backend the host supports:
//!   NEON on aarch64 (a baseline feature of every `aarch64-linux`
//!   target, so no runtime probe is needed), AVX2 on x86_64 when
//!   `is_x86_feature_detected!` confirms it, scalar otherwise.
//! * Forcing a backend the host lacks is a LOAD-TIME error ([`select`]
//!   refuses), never a crash: an unsupported kernel table is never
//!   installed, which is exactly the safety contract that keeps the
//!   `unsafe` AVX2 entry points sound.
//! * The scalar backend is always available and is THE reference
//!   implementation: the fixed `LANES = 8` accumulator tree of
//!   [`crate::tensor::matvec::dot_f32`] and friends.
//!
//! # Bit-identity contract
//!
//! Every SIMD kernel replicates the scalar reference's floating-point
//! operation sequence EXACTLY:
//!
//! * the same per-lane products — multiplies and adds stay separate
//!   instructions (no FMA contraction, which would skip the scalar
//!   code's intermediate rounding);
//! * the same 8 partial sums, reduced in the same ascending lane order
//!   (`acc.iter().sum()` is a sequential left fold);
//! * the same scalar tail loop over the last `n % 8` elements;
//! * the same decode arithmetic — [`crate::util::f16::f16_to_f32_fast`]'s
//!   magic-multiply bit recipe and [`crate::tensor::q4::dq4`] /
//!   [`crate::tensor::q4::dq4_1`]'s `s * (q - 8)` / `s * q + m` with the
//!   scalar association preserved.
//!
//! So every backend is bit-identical to scalar for every input —
//! `tests/simd_equivalence.rs` pins this per kernel and dtype, ragged
//! shapes included.  That is what lets the engine treat `--simd` as a
//! pure performance knob: all standing equivalence invariants (batched
//! == per-slot, any thread count, prefetch on == off, warm == cold)
//! hold across backends too.
//!
//! The selected backend lives in a process-global `AtomicU8` — a
//! documented `crate::sync` exception (see `sync/mod.rs`): loom atomics
//! cannot const-initialize a `static`, and this is a write-once
//! configuration byte with no cross-thread protocol.  Tests and benches
//! that need a specific backend use [`kernels_for`], which never touches
//! the global selection.

use std::sync::atomic::{AtomicU8, Ordering};

use anyhow::{bail, Result};

use crate::tensor::{matvec, q4};

/// Instruction-set backend for one [`Kernels`] table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdBackend {
    /// The reference implementation (matvec.rs / q4.rs) — always available.
    Scalar,
    /// aarch64 NEON (baseline on aarch64-linux targets).
    Neon,
    /// x86_64 AVX2 (gated on `is_x86_feature_detected!("avx2")`).
    Avx2,
}

impl SimdBackend {
    /// The CLI / telemetry name (`--simd` accepts these plus `auto`).
    pub fn name(self) -> &'static str {
        match self {
            SimdBackend::Scalar => "scalar",
            SimdBackend::Neon => "neon",
            SimdBackend::Avx2 => "avx2",
        }
    }

    /// Stable small id for the telemetry gauge and the `ACTIVE` byte.
    pub fn as_u8(self) -> u8 {
        match self {
            SimdBackend::Scalar => 0,
            SimdBackend::Neon => 1,
            SimdBackend::Avx2 => 2,
        }
    }

    fn from_u8(v: u8) -> Option<Self> {
        match v {
            0 => Some(SimdBackend::Scalar),
            1 => Some(SimdBackend::Neon),
            2 => Some(SimdBackend::Avx2),
            _ => None,
        }
    }
}

/// One resolved kernel set: every hot inner loop as a plain `fn`
/// pointer, so callers pay the backend dispatch once per matrix pass
/// instead of once per element or row.
///
/// Semantics (each bit-identical to its scalar reference):
///
/// * `dot_*`: `sum_k row[k] * x[k]` with the LANES=8 accumulator tree
///   (i8 is UNSCALED — callers fold the per-row scale, as with
///   [`crate::tensor::matvec::dot_i8`]); the q4 forms fuse group-scale
///   dequant into the dot.
/// * `widen_*`: decode a row (window) into f32 scratch; the q4 forms
///   take the window's starting GLOBAL column `c0` so group scales
///   resolve identically to the full-row decode.
/// * `axpy_*`: `out[k] += a * row[k]` with dequant fused (i8 again
///   unscaled — callers fold per-column scales exactly as before).
#[derive(Clone, Copy)]
pub struct Kernels {
    /// Which instruction set this table runs on.
    pub backend: SimdBackend,
    pub dot_f32: fn(&[f32], &[f32]) -> f32,
    pub dot_f16: fn(&[u16], &[f32]) -> f32,
    pub dot_i8: fn(&[i8], &[f32]) -> f32,
    pub dot_q4: fn(&[u8], &[u16], &[f32]) -> f32,
    pub dot_q4_1: fn(&[u8], &[u16], &[u16], &[f32]) -> f32,
    pub widen_f16: fn(&[u16], &mut [f32]),
    pub widen_q4: fn(&[u8], &[u16], usize, &mut [f32]),
    pub widen_q4_1: fn(&[u8], &[u16], &[u16], usize, &mut [f32]),
    pub axpy_f32: fn(f32, &[f32], &mut [f32]),
    pub axpy_f16: fn(f32, &[u16], &mut [f32]),
    pub axpy_i8: fn(f32, &[i8], &mut [f32]),
    pub axpy_q4: fn(f32, &[u8], &[u16], usize, &mut [f32]),
    pub axpy_q4_1: fn(f32, &[u8], &[u16], &[u16], usize, &mut [f32]),
}

/// `ACTIVE` value before the first [`select`] call.
const UNSET: u8 = u8::MAX;

/// The selected backend as `SimdBackend::as_u8` (or [`UNSET`]).
/// Deliberately `std::sync::atomic`, NOT `crate::sync::atomic` — the
/// documented shim exception: loom's atomics cannot const-initialize a
/// `static`, and this is a write-once configuration byte with no
/// cross-thread protocol (all installable backends are bit-identical).
static ACTIVE: AtomicU8 = AtomicU8::new(UNSET);

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

/// Best backend this host supports — what `--simd auto` picks.
pub fn detect() -> SimdBackend {
    if cfg!(target_arch = "aarch64") {
        SimdBackend::Neon
    } else if avx2_available() {
        SimdBackend::Avx2
    } else {
        SimdBackend::Scalar
    }
}

/// Whether `b`'s kernel table can run on this host.
pub fn available(b: SimdBackend) -> bool {
    match b {
        SimdBackend::Scalar => true,
        SimdBackend::Neon => cfg!(target_arch = "aarch64"),
        SimdBackend::Avx2 => avx2_available(),
    }
}

/// Install the process-wide backend: `None` = auto-detect, `Some(b)` =
/// force `b` — refused with an error if this host cannot run it, so an
/// unsupported table is never installed.  Called once from
/// `RwkvEngine::load_with_pool`; before any call, [`kernels`] dispatches
/// to [`detect`]'s choice.
pub fn select(requested: Option<SimdBackend>) -> Result<SimdBackend> {
    let b = match requested {
        None => detect(),
        Some(b) if available(b) => b,
        Some(b) => bail!(
            "simd backend '{}' is not available on this host (auto would pick '{}')",
            b.name(),
            detect().name()
        ),
    };
    ACTIVE.store(b.as_u8(), Ordering::Relaxed);
    Ok(b)
}

/// The backend [`kernels`] currently dispatches to.
pub fn active() -> SimdBackend {
    SimdBackend::from_u8(ACTIVE.load(Ordering::Relaxed)).unwrap_or_else(detect)
}

/// The active kernel table.  Resolve once per matrix pass, then call
/// through the `fn` pointers.
pub fn kernels() -> &'static Kernels {
    table(active())
}

/// The kernel table for `b`, or `None` if this host cannot run it — the
/// side-effect-free accessor the dispatch-equivalence tests and the
/// matvec bench use (never touches the global selection).
pub fn kernels_for(b: SimdBackend) -> Option<&'static Kernels> {
    if available(b) {
        Some(table(b))
    } else {
        None
    }
}

fn table(b: SimdBackend) -> &'static Kernels {
    match b {
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon => &NEON,
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2 => &AVX2,
        _ => &SCALAR,
    }
}

// ---------------------------------------------------------------------------
// Scalar backend — the always-available reference
// ---------------------------------------------------------------------------

static SCALAR: Kernels = Kernels {
    backend: SimdBackend::Scalar,
    dot_f32: matvec::dot_f32,
    dot_f16: matvec::dot_f16,
    dot_i8: matvec::dot_i8,
    dot_q4: q4::dot_q4,
    dot_q4_1: q4::dot_q4_1,
    widen_f16: scalar::widen_f16,
    widen_q4: scalar::widen_q4,
    widen_q4_1: scalar::widen_q4_1,
    axpy_f32: scalar::axpy_f32,
    axpy_f16: scalar::axpy_f16,
    axpy_i8: scalar::axpy_i8,
    axpy_q4: scalar::axpy_q4,
    axpy_q4_1: scalar::axpy_q4_1,
};

/// Scalar widen/axpy — the exact loops the matvec/matmat dtype arms used
/// inline before the kernel table existed (the dots live in matvec.rs /
/// q4.rs and are referenced directly by [`SCALAR`]).
mod scalar {
    use crate::tensor::q4::{dq4, dq4_1};
    use crate::util::f16::f16_to_f32_fast as f16_to_f32;

    pub fn widen_f16(src: &[u16], out: &mut [f32]) {
        for (o, &h) in out.iter_mut().zip(src) {
            *o = f16_to_f32(h);
        }
    }

    pub fn widen_q4(prow: &[u8], srow: &[u16], c0: usize, out: &mut [f32]) {
        for (k, o) in out.iter_mut().enumerate() {
            *o = dq4(prow, srow, c0 + k);
        }
    }

    pub fn widen_q4_1(prow: &[u8], srow: &[u16], mrow: &[u16], c0: usize, out: &mut [f32]) {
        for (k, o) in out.iter_mut().enumerate() {
            *o = dq4_1(prow, srow, mrow, c0 + k);
        }
    }

    pub fn axpy_f32(a: f32, row: &[f32], out: &mut [f32]) {
        for (o, &w) in out.iter_mut().zip(row) {
            *o += a * w;
        }
    }

    pub fn axpy_f16(a: f32, row: &[u16], out: &mut [f32]) {
        for (o, &h) in out.iter_mut().zip(row) {
            *o += a * f16_to_f32(h);
        }
    }

    pub fn axpy_i8(a: f32, row: &[i8], out: &mut [f32]) {
        for (o, &q) in out.iter_mut().zip(row) {
            *o += a * q as f32;
        }
    }

    pub fn axpy_q4(a: f32, prow: &[u8], srow: &[u16], c0: usize, out: &mut [f32]) {
        for (k, o) in out.iter_mut().enumerate() {
            *o += a * dq4(prow, srow, c0 + k);
        }
    }

    pub fn axpy_q4_1(
        a: f32,
        prow: &[u8],
        srow: &[u16],
        mrow: &[u16],
        c0: usize,
        out: &mut [f32],
    ) {
        for (k, o) in out.iter_mut().enumerate() {
            *o += a * dq4_1(prow, srow, mrow, c0 + k);
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 backend (x86_64, runtime-detected)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    backend: SimdBackend::Avx2,
    dot_f32: avx2::dot_f32,
    dot_f16: avx2::dot_f16,
    dot_i8: avx2::dot_i8,
    dot_q4: avx2::dot_q4,
    dot_q4_1: avx2::dot_q4_1,
    widen_f16: avx2::widen_f16,
    widen_q4: avx2::widen_q4,
    widen_q4_1: avx2::widen_q4_1,
    axpy_f32: avx2::axpy_f32,
    axpy_f16: avx2::axpy_f16,
    axpy_i8: avx2::axpy_i8,
    axpy_q4: avx2::axpy_q4,
    axpy_q4_1: avx2::axpy_q4_1,
};

/// AVX2 kernels.  Every `#[target_feature]` impl is `unsafe fn` whose
/// contract is "this CPU has AVX2"; the safe `pub fn` wrappers discharge
/// it because the [`AVX2`] table is only reachable through
/// [`kernels_for`] / [`select`], both gated on runtime detection.
///
/// 256-bit lanes map 1:1 onto the scalar reference's `[f32; 8]`
/// accumulator: one vector add per chunk keeps the identical 8 partial
/// sums, and the horizontal reduce stores the register and sums lanes
/// 0..8 sequentially — the same left fold as `acc.iter().sum()`.
/// Multiplies and adds are separate intrinsics throughout (no FMA).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use crate::tensor::q4::{dq4, dq4_1, spread_nibbles8, Q4_GROUP};
    use crate::util::f16::f16_to_f32_fast as f16_to_f32;

    const LANES: usize = 8;

    /// `f16_to_f32_fast`'s magic multiplier (2^112) as f32 bits.
    const F16_MAGIC: i32 = 0x7780_0000;

    /// Reduce 8 lanes in ascending lane order — the exact sequential
    /// left fold of the scalar reference's `acc.iter().sum()`.
    ///
    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut lanes = [0f32; LANES];
        // SAFETY: `lanes` holds 8 writable f32s; storeu is unaligned-ok.
        unsafe { _mm256_storeu_ps(lanes.as_mut_ptr(), v) };
        lanes.iter().sum()
    }

    /// Decode 8 f16 values at `p` with the `f16_to_f32_fast` bit recipe.
    ///
    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2 and that 8 readable
    /// `u16`s exist at `p`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load_f16x8(p: *const u16) -> __m256 {
        // SAFETY: 8 u16s at `p` per the fn contract (loadu is
        // unaligned-ok); the integer ops replicate f16_to_f32_fast —
        // (mag << 13) * 2^112, sign bit OR'd back in.
        unsafe {
            let h = _mm256_cvtepu16_epi32(_mm_loadu_si128(p as *const __m128i));
            let mag = _mm256_and_si256(h, _mm256_set1_epi32(0x7fff));
            let sign = _mm256_slli_epi32::<16>(_mm256_and_si256(h, _mm256_set1_epi32(0x8000)));
            let val = _mm256_mul_ps(
                _mm256_castsi256_ps(_mm256_slli_epi32::<13>(mag)),
                _mm256_castsi256_ps(_mm256_set1_epi32(F16_MAGIC)),
            );
            _mm256_castsi256_ps(_mm256_or_si256(_mm256_castps_si256(val), sign))
        }
    }

    /// 8 unsigned 4-bit codes covering global columns `[g, g+8)` as i32
    /// lanes (`g` must be 8-aligned: the chunk then sits on packed-byte
    /// boundaries and inside one 32-wide scale group).
    ///
    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2 and that 4 readable
    /// bytes exist at `p + g/2`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn q4_codes_x8(p: *const u8, g: usize) -> __m256i {
        // SAFETY: 4 bytes at p + g/2 per the fn contract; the nibble
        // spread is the shared q4.rs recipe, then pure register widening.
        unsafe {
            let v = u32::from_le((p.add(g / 2) as *const u32).read_unaligned());
            _mm256_cvtepu8_epi32(_mm_set_epi64x(0, spread_nibbles8(v) as i64))
        }
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_f32_impl(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let full = n - n % LANES;
        // SAFETY: loads read lanes [c, c+8) with c+8 <= full <= both
        // slice lengths — in bounds, unaligned-ok (loadu).
        let mut s = unsafe {
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc = _mm256_setzero_ps();
            let mut c = 0;
            while c < full {
                let va = _mm256_loadu_ps(pa.add(c));
                let vb = _mm256_loadu_ps(pb.add(c));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
                c += LANES;
            }
            hsum(acc)
        };
        for i in full..n {
            s += a[i] * b[i];
        }
        s
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_f16_impl(a: &[u16], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let full = n - n % LANES;
        // SAFETY: loads read lanes [c, c+8) with c+8 <= full <= both
        // slice lengths — in bounds, unaligned-ok.
        let mut s = unsafe {
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc = _mm256_setzero_ps();
            let mut c = 0;
            while c < full {
                let w = load_f16x8(pa.add(c));
                let vb = _mm256_loadu_ps(pb.add(c));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(w, vb));
                c += LANES;
            }
            hsum(acc)
        };
        for i in full..n {
            s += f16_to_f32(a[i]) * b[i];
        }
        s
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_i8_impl(a: &[i8], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let full = n - n % LANES;
        // SAFETY: loads read lanes [c, c+8) with c+8 <= full <= both
        // slice lengths — in bounds, unaligned-ok.
        let mut s = unsafe {
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc = _mm256_setzero_ps();
            let mut c = 0;
            while c < full {
                let q = _mm256_cvtepi8_epi32(_mm_loadl_epi64(pa.add(c) as *const __m128i));
                let w = _mm256_cvtepi32_ps(q);
                let vb = _mm256_loadu_ps(pb.add(c));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(w, vb));
                c += LANES;
            }
            hsum(acc)
        };
        for i in full..n {
            s += a[i] as f32 * b[i];
        }
        s
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_q4_impl(packed_row: &[u8], scale_row: &[u16], x: &[f32]) -> f32 {
        let n = x.len();
        let full = n - n % LANES;
        // SAFETY: each chunk [c, c+8) has 8-aligned c, so it reads 4
        // packed bytes at c/2 (c/2 + 4 <= n/2 <= the row's ceil(n/2)
        // packed bytes) and x lanes [c, c+8) <= full <= n — in bounds.
        let mut s = unsafe {
            let (pp, px) = (packed_row.as_ptr(), x.as_ptr());
            let mut acc = _mm256_setzero_ps();
            let eight = _mm256_set1_epi32(8);
            let mut c = 0;
            while c < full {
                // one group scale per chunk: 8 divides Q4_GROUP, so an
                // 8-aligned chunk never straddles a group boundary
                let sv = _mm256_set1_ps(f16_to_f32(scale_row[c / Q4_GROUP]));
                let q = _mm256_cvtepi32_ps(_mm256_sub_epi32(q4_codes_x8(pp, c), eight));
                // dq4 = s * (q - 8), then * x — scalar association kept
                let w = _mm256_mul_ps(sv, q);
                let vx = _mm256_loadu_ps(px.add(c));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(w, vx));
                c += LANES;
            }
            hsum(acc)
        };
        for i in full..n {
            s += dq4(packed_row, scale_row, i) * x[i];
        }
        s
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_q4_1_impl(
        packed_row: &[u8],
        scale_row: &[u16],
        min_row: &[u16],
        x: &[f32],
    ) -> f32 {
        let n = x.len();
        let full = n - n % LANES;
        // SAFETY: same bounds argument as the q4 dot above.
        let mut s = unsafe {
            let (pp, px) = (packed_row.as_ptr(), x.as_ptr());
            let mut acc = _mm256_setzero_ps();
            let mut c = 0;
            while c < full {
                let g = c / Q4_GROUP;
                let sv = _mm256_set1_ps(f16_to_f32(scale_row[g]));
                let mv = _mm256_set1_ps(f16_to_f32(min_row[g]));
                let q = _mm256_cvtepi32_ps(q4_codes_x8(pp, c));
                // dq4_1 = s * q + m (mul then add, two roundings), * x
                let w = _mm256_add_ps(_mm256_mul_ps(sv, q), mv);
                let vx = _mm256_loadu_ps(px.add(c));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(w, vx));
                c += LANES;
            }
            hsum(acc)
        };
        for i in full..n {
            s += dq4_1(packed_row, scale_row, min_row, i) * x[i];
        }
        s
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn widen_f16_impl(src: &[u16], out: &mut [f32]) {
        let n = out.len().min(src.len());
        let full = n - n % LANES;
        // SAFETY: reads src[c..c+8) and writes out[c..c+8) with c+8 <=
        // full <= both lengths — in bounds, unaligned-ok.
        unsafe {
            let (ps, po) = (src.as_ptr(), out.as_mut_ptr());
            let mut c = 0;
            while c < full {
                _mm256_storeu_ps(po.add(c), load_f16x8(ps.add(c)));
                c += LANES;
            }
        }
        for i in full..n {
            out[i] = f16_to_f32(src[i]);
        }
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn widen_q4_impl(prow: &[u8], srow: &[u16], c0: usize, out: &mut [f32]) {
        let n = out.len();
        // scalar head until the GLOBAL column index is 8-aligned (column
        // windows may start mid-byte / mid-group — matmat shards do)
        let head = ((LANES - c0 % LANES) % LANES).min(n);
        for (k, o) in out[..head].iter_mut().enumerate() {
            *o = dq4(prow, srow, c0 + k);
        }
        let body = (n - head) / LANES * LANES;
        // SAFETY: every chunk covers global columns [g, g+8) with g
        // 8-aligned — 4 packed bytes at g/2 (within the row: g+8 <=
        // c0+n <= cols), one scale group; out writes stay < head+body.
        unsafe {
            let (pp, po) = (prow.as_ptr(), out.as_mut_ptr());
            let eight = _mm256_set1_epi32(8);
            let mut k = head;
            while k < head + body {
                let g = c0 + k;
                let sv = _mm256_set1_ps(f16_to_f32(srow[g / Q4_GROUP]));
                let q = _mm256_cvtepi32_ps(_mm256_sub_epi32(q4_codes_x8(pp, g), eight));
                _mm256_storeu_ps(po.add(k), _mm256_mul_ps(sv, q));
                k += LANES;
            }
        }
        for k in head + body..n {
            out[k] = dq4(prow, srow, c0 + k);
        }
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn widen_q4_1_impl(
        prow: &[u8],
        srow: &[u16],
        mrow: &[u16],
        c0: usize,
        out: &mut [f32],
    ) {
        let n = out.len();
        let head = ((LANES - c0 % LANES) % LANES).min(n);
        for (k, o) in out[..head].iter_mut().enumerate() {
            *o = dq4_1(prow, srow, mrow, c0 + k);
        }
        let body = (n - head) / LANES * LANES;
        // SAFETY: same bounds argument as widen_q4_impl.
        unsafe {
            let (pp, po) = (prow.as_ptr(), out.as_mut_ptr());
            let mut k = head;
            while k < head + body {
                let g = c0 + k;
                let grp = g / Q4_GROUP;
                let sv = _mm256_set1_ps(f16_to_f32(srow[grp]));
                let mv = _mm256_set1_ps(f16_to_f32(mrow[grp]));
                let q = _mm256_cvtepi32_ps(q4_codes_x8(pp, g));
                _mm256_storeu_ps(po.add(k), _mm256_add_ps(_mm256_mul_ps(sv, q), mv));
                k += LANES;
            }
        }
        for k in head + body..n {
            out[k] = dq4_1(prow, srow, mrow, c0 + k);
        }
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn axpy_f32_impl(a: f32, row: &[f32], out: &mut [f32]) {
        let n = out.len().min(row.len());
        let full = n - n % LANES;
        // SAFETY: reads row[c..c+8) and read-modify-writes out[c..c+8)
        // with c+8 <= full <= both lengths — in bounds, unaligned-ok.
        unsafe {
            let (pw, po) = (row.as_ptr(), out.as_mut_ptr());
            let av = _mm256_set1_ps(a);
            let mut c = 0;
            while c < full {
                let o = _mm256_loadu_ps(po.add(c));
                let w = _mm256_loadu_ps(pw.add(c));
                _mm256_storeu_ps(po.add(c), _mm256_add_ps(o, _mm256_mul_ps(av, w)));
                c += LANES;
            }
        }
        for i in full..n {
            out[i] += a * row[i];
        }
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn axpy_f16_impl(a: f32, row: &[u16], out: &mut [f32]) {
        let n = out.len().min(row.len());
        let full = n - n % LANES;
        // SAFETY: same bounds argument as axpy_f32_impl.
        unsafe {
            let (pw, po) = (row.as_ptr(), out.as_mut_ptr());
            let av = _mm256_set1_ps(a);
            let mut c = 0;
            while c < full {
                let o = _mm256_loadu_ps(po.add(c));
                let w = load_f16x8(pw.add(c));
                _mm256_storeu_ps(po.add(c), _mm256_add_ps(o, _mm256_mul_ps(av, w)));
                c += LANES;
            }
        }
        for i in full..n {
            out[i] += a * f16_to_f32(row[i]);
        }
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn axpy_i8_impl(a: f32, row: &[i8], out: &mut [f32]) {
        let n = out.len().min(row.len());
        let full = n - n % LANES;
        // SAFETY: same bounds argument as axpy_f32_impl.
        unsafe {
            let (pw, po) = (row.as_ptr(), out.as_mut_ptr());
            let av = _mm256_set1_ps(a);
            let mut c = 0;
            while c < full {
                let o = _mm256_loadu_ps(po.add(c));
                let q = _mm256_cvtepi8_epi32(_mm_loadl_epi64(pw.add(c) as *const __m128i));
                let w = _mm256_cvtepi32_ps(q);
                _mm256_storeu_ps(po.add(c), _mm256_add_ps(o, _mm256_mul_ps(av, w)));
                c += LANES;
            }
        }
        for i in full..n {
            out[i] += a * row[i] as f32;
        }
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn axpy_q4_impl(a: f32, prow: &[u8], srow: &[u16], c0: usize, out: &mut [f32]) {
        let n = out.len();
        let head = ((LANES - c0 % LANES) % LANES).min(n);
        for (k, o) in out[..head].iter_mut().enumerate() {
            *o += a * dq4(prow, srow, c0 + k);
        }
        let body = (n - head) / LANES * LANES;
        // SAFETY: same bounds argument as widen_q4_impl, plus the
        // read-modify-write of out[k..k+8) stays below head+body <= n.
        unsafe {
            let (pp, po) = (prow.as_ptr(), out.as_mut_ptr());
            let av = _mm256_set1_ps(a);
            let eight = _mm256_set1_epi32(8);
            let mut k = head;
            while k < head + body {
                let g = c0 + k;
                let sv = _mm256_set1_ps(f16_to_f32(srow[g / Q4_GROUP]));
                let q = _mm256_cvtepi32_ps(_mm256_sub_epi32(q4_codes_x8(pp, g), eight));
                // a * dq4 = a * (s * (q-8)) — scalar association kept
                let w = _mm256_mul_ps(av, _mm256_mul_ps(sv, q));
                let o = _mm256_loadu_ps(po.add(k));
                _mm256_storeu_ps(po.add(k), _mm256_add_ps(o, w));
                k += LANES;
            }
        }
        for k in head + body..n {
            out[k] += a * dq4(prow, srow, c0 + k);
        }
    }

    /// # Safety
    ///
    /// Caller must ensure this CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn axpy_q4_1_impl(
        a: f32,
        prow: &[u8],
        srow: &[u16],
        mrow: &[u16],
        c0: usize,
        out: &mut [f32],
    ) {
        let n = out.len();
        let head = ((LANES - c0 % LANES) % LANES).min(n);
        for (k, o) in out[..head].iter_mut().enumerate() {
            *o += a * dq4_1(prow, srow, mrow, c0 + k);
        }
        let body = (n - head) / LANES * LANES;
        // SAFETY: same bounds argument as axpy_q4_impl.
        unsafe {
            let (pp, po) = (prow.as_ptr(), out.as_mut_ptr());
            let av = _mm256_set1_ps(a);
            let mut k = head;
            while k < head + body {
                let g = c0 + k;
                let grp = g / Q4_GROUP;
                let sv = _mm256_set1_ps(f16_to_f32(srow[grp]));
                let mv = _mm256_set1_ps(f16_to_f32(mrow[grp]));
                let q = _mm256_cvtepi32_ps(q4_codes_x8(pp, g));
                let w = _mm256_mul_ps(av, _mm256_add_ps(_mm256_mul_ps(sv, q), mv));
                let o = _mm256_loadu_ps(po.add(k));
                _mm256_storeu_ps(po.add(k), _mm256_add_ps(o, w));
                k += LANES;
            }
        }
        for k in head + body..n {
            out[k] += a * dq4_1(prow, srow, mrow, c0 + k);
        }
    }

    // Safe table entry points: the AVX2 table is only handed out by
    // `kernels_for` / installed by `select` after a positive
    // `is_x86_feature_detected!("avx2")`, which discharges every
    // `unsafe fn` contract above.

    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { dot_f32_impl(a, b) }
    }

    pub fn dot_f16(a: &[u16], b: &[f32]) -> f32 {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { dot_f16_impl(a, b) }
    }

    pub fn dot_i8(a: &[i8], b: &[f32]) -> f32 {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { dot_i8_impl(a, b) }
    }

    pub fn dot_q4(packed_row: &[u8], scale_row: &[u16], x: &[f32]) -> f32 {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { dot_q4_impl(packed_row, scale_row, x) }
    }

    pub fn dot_q4_1(packed_row: &[u8], scale_row: &[u16], min_row: &[u16], x: &[f32]) -> f32 {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { dot_q4_1_impl(packed_row, scale_row, min_row, x) }
    }

    pub fn widen_f16(src: &[u16], out: &mut [f32]) {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { widen_f16_impl(src, out) }
    }

    pub fn widen_q4(prow: &[u8], srow: &[u16], c0: usize, out: &mut [f32]) {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { widen_q4_impl(prow, srow, c0, out) }
    }

    pub fn widen_q4_1(prow: &[u8], srow: &[u16], mrow: &[u16], c0: usize, out: &mut [f32]) {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { widen_q4_1_impl(prow, srow, mrow, c0, out) }
    }

    pub fn axpy_f32(a: f32, row: &[f32], out: &mut [f32]) {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { axpy_f32_impl(a, row, out) }
    }

    pub fn axpy_f16(a: f32, row: &[u16], out: &mut [f32]) {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { axpy_f16_impl(a, row, out) }
    }

    pub fn axpy_i8(a: f32, row: &[i8], out: &mut [f32]) {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { axpy_i8_impl(a, row, out) }
    }

    pub fn axpy_q4(a: f32, prow: &[u8], srow: &[u16], c0: usize, out: &mut [f32]) {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { axpy_q4_impl(a, prow, srow, c0, out) }
    }

    pub fn axpy_q4_1(a: f32, prow: &[u8], srow: &[u16], mrow: &[u16], c0: usize, out: &mut [f32]) {
        // SAFETY: AVX2 verified at table selection (module docs).
        unsafe { axpy_q4_1_impl(a, prow, srow, mrow, c0, out) }
    }
}

// ---------------------------------------------------------------------------
// NEON backend (aarch64 baseline)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    backend: SimdBackend::Neon,
    dot_f32: neon::dot_f32,
    dot_f16: neon::dot_f16,
    dot_i8: neon::dot_i8,
    dot_q4: neon::dot_q4,
    dot_q4_1: neon::dot_q4_1,
    widen_f16: neon::widen_f16,
    widen_q4: neon::widen_q4,
    widen_q4_1: neon::widen_q4_1,
    axpy_f32: neon::axpy_f32,
    axpy_f16: neon::axpy_f16,
    axpy_i8: neon::axpy_i8,
    axpy_q4: neon::axpy_q4,
    axpy_q4_1: neon::axpy_q4_1,
};

/// NEON kernels (the paper's §4 target ISA).  NEON is a baseline feature
/// of the aarch64 targets this crate builds for, so the entry points are
/// plain safe functions; the remaining `unsafe` is pointer loads/stores,
/// discharged by slice bounds as documented per block.
///
/// The scalar reference's `[f32; 8]` accumulator maps onto TWO
/// `float32x4_t` registers (lanes 0–3 / 4–7); the horizontal reduce
/// stores both and sums lanes 0..8 sequentially — the same left fold as
/// `acc.iter().sum()`.  Multiplies and adds are separate intrinsics
/// throughout (no `vfmaq`, which would skip the scalar code's
/// intermediate rounding).
#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    use crate::tensor::q4::{dq4, dq4_1, spread_nibbles8, Q4_GROUP};
    use crate::util::f16::f16_to_f32_fast as f16_to_f32;

    const LANES: usize = 8;

    /// `f16_to_f32_fast`'s magic multiplier (2^112) as f32 bits.
    const F16_MAGIC: u32 = 0x7780_0000;

    /// Reduce the 8 lanes (lo = 0–3, hi = 4–7) in ascending lane order —
    /// the exact sequential left fold of `acc.iter().sum()`.
    fn hsum8(lo: float32x4_t, hi: float32x4_t) -> f32 {
        let mut lanes = [0f32; LANES];
        // SAFETY: `lanes` holds 8 writable f32s (4 at offset 0, 4 at 4).
        unsafe {
            vst1q_f32(lanes.as_mut_ptr(), lo);
            vst1q_f32(lanes.as_mut_ptr().add(4), hi);
        }
        lanes.iter().sum()
    }

    /// Decode 8 f16 values at `p` with the `f16_to_f32_fast` bit recipe,
    /// returning (lanes 0–3, lanes 4–7).
    ///
    /// # Safety
    ///
    /// Caller must ensure 8 readable `u16`s exist at `p`.
    #[inline]
    unsafe fn load_f16x8(p: *const u16) -> (float32x4_t, float32x4_t) {
        // SAFETY: 8 u16s at `p` per the fn contract; the integer ops
        // replicate f16_to_f32_fast — (mag << 13) * 2^112, sign OR'd in.
        unsafe {
            let h = vld1q_u16(p);
            let mag = vandq_u16(h, vdupq_n_u16(0x7fff));
            let sgn = vandq_u16(h, vdupq_n_u16(0x8000));
            let magic = vdupq_n_f32(f32::from_bits(F16_MAGIC));
            let lo = {
                let m = vshlq_n_u32::<13>(vmovl_u16(vget_low_u16(mag)));
                let s = vshlq_n_u32::<16>(vmovl_u16(vget_low_u16(sgn)));
                let val = vmulq_f32(vreinterpretq_f32_u32(m), magic);
                vreinterpretq_f32_u32(vorrq_u32(vreinterpretq_u32_f32(val), s))
            };
            let hi = {
                let m = vshlq_n_u32::<13>(vmovl_u16(vget_high_u16(mag)));
                let s = vshlq_n_u32::<16>(vmovl_u16(vget_high_u16(sgn)));
                let val = vmulq_f32(vreinterpretq_f32_u32(m), magic);
                vreinterpretq_f32_u32(vorrq_u32(vreinterpretq_u32_f32(val), s))
            };
            (lo, hi)
        }
    }

    /// 8 unsigned 4-bit codes covering global columns `[g, g+8)` as i32
    /// lanes (lo = 0–3, hi = 4–7); `g` must be 8-aligned so the chunk
    /// sits on packed-byte boundaries and inside one scale group.
    ///
    /// # Safety
    ///
    /// Caller must ensure 4 readable bytes exist at `p + g/2`.
    #[inline]
    unsafe fn q4_codes_x8(p: *const u8, g: usize) -> (int32x4_t, int32x4_t) {
        // SAFETY: 4 bytes at p + g/2 per the fn contract; the nibble
        // spread is the shared q4.rs recipe, then pure register widening.
        unsafe {
            let v = u32::from_le((p.add(g / 2) as *const u32).read_unaligned());
            let n16 = vmovl_u8(vcreate_u8(spread_nibbles8(v)));
            let lo = vreinterpretq_s32_u32(vmovl_u16(vget_low_u16(n16)));
            let hi = vreinterpretq_s32_u32(vmovl_u16(vget_high_u16(n16)));
            (lo, hi)
        }
    }

    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let full = n - n % LANES;
        // SAFETY: loads read lanes [c, c+8) with c+8 <= full <= both
        // slice lengths — in bounds (vld1q has no alignment requirement).
        let mut s = unsafe {
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut c = 0;
            while c < full {
                acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(pa.add(c)), vld1q_f32(pb.add(c))));
                acc1 = vaddq_f32(
                    acc1,
                    vmulq_f32(vld1q_f32(pa.add(c + 4)), vld1q_f32(pb.add(c + 4))),
                );
                c += LANES;
            }
            hsum8(acc0, acc1)
        };
        for i in full..n {
            s += a[i] * b[i];
        }
        s
    }

    pub fn dot_f16(a: &[u16], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let full = n - n % LANES;
        // SAFETY: loads read lanes [c, c+8) with c+8 <= full <= both
        // slice lengths — in bounds.
        let mut s = unsafe {
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut c = 0;
            while c < full {
                let (w0, w1) = load_f16x8(pa.add(c));
                acc0 = vaddq_f32(acc0, vmulq_f32(w0, vld1q_f32(pb.add(c))));
                acc1 = vaddq_f32(acc1, vmulq_f32(w1, vld1q_f32(pb.add(c + 4))));
                c += LANES;
            }
            hsum8(acc0, acc1)
        };
        for i in full..n {
            s += f16_to_f32(a[i]) * b[i];
        }
        s
    }

    pub fn dot_i8(a: &[i8], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let full = n - n % LANES;
        // SAFETY: loads read lanes [c, c+8) with c+8 <= full <= both
        // slice lengths — in bounds.
        let mut s = unsafe {
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut c = 0;
            while c < full {
                let q = vmovl_s8(vld1_s8(pa.add(c)));
                let w0 = vcvtq_f32_s32(vmovl_s16(vget_low_s16(q)));
                let w1 = vcvtq_f32_s32(vmovl_s16(vget_high_s16(q)));
                acc0 = vaddq_f32(acc0, vmulq_f32(w0, vld1q_f32(pb.add(c))));
                acc1 = vaddq_f32(acc1, vmulq_f32(w1, vld1q_f32(pb.add(c + 4))));
                c += LANES;
            }
            hsum8(acc0, acc1)
        };
        for i in full..n {
            s += a[i] as f32 * b[i];
        }
        s
    }

    pub fn dot_q4(packed_row: &[u8], scale_row: &[u16], x: &[f32]) -> f32 {
        let n = x.len();
        let full = n - n % LANES;
        // SAFETY: each chunk [c, c+8) has 8-aligned c, so it reads 4
        // packed bytes at c/2 (c/2 + 4 <= n/2 <= the row's ceil(n/2)
        // packed bytes) and x lanes [c, c+8) <= full <= n — in bounds.
        let mut s = unsafe {
            let (pp, px) = (packed_row.as_ptr(), x.as_ptr());
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let eight = vdupq_n_s32(8);
            let mut c = 0;
            while c < full {
                // one group scale per chunk: 8 divides Q4_GROUP
                let sv = vdupq_n_f32(f16_to_f32(scale_row[c / Q4_GROUP]));
                let (q0, q1) = q4_codes_x8(pp, c);
                // dq4 = s * (q - 8), then * x — scalar association kept
                let w0 = vmulq_f32(sv, vcvtq_f32_s32(vsubq_s32(q0, eight)));
                let w1 = vmulq_f32(sv, vcvtq_f32_s32(vsubq_s32(q1, eight)));
                acc0 = vaddq_f32(acc0, vmulq_f32(w0, vld1q_f32(px.add(c))));
                acc1 = vaddq_f32(acc1, vmulq_f32(w1, vld1q_f32(px.add(c + 4))));
                c += LANES;
            }
            hsum8(acc0, acc1)
        };
        for i in full..n {
            s += dq4(packed_row, scale_row, i) * x[i];
        }
        s
    }

    pub fn dot_q4_1(packed_row: &[u8], scale_row: &[u16], min_row: &[u16], x: &[f32]) -> f32 {
        let n = x.len();
        let full = n - n % LANES;
        // SAFETY: same bounds argument as the q4 dot above.
        let mut s = unsafe {
            let (pp, px) = (packed_row.as_ptr(), x.as_ptr());
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            let mut c = 0;
            while c < full {
                let g = c / Q4_GROUP;
                let sv = vdupq_n_f32(f16_to_f32(scale_row[g]));
                let mv = vdupq_n_f32(f16_to_f32(min_row[g]));
                let (q0, q1) = q4_codes_x8(pp, c);
                // dq4_1 = s * q + m (mul then add), * x
                let w0 = vaddq_f32(vmulq_f32(sv, vcvtq_f32_s32(q0)), mv);
                let w1 = vaddq_f32(vmulq_f32(sv, vcvtq_f32_s32(q1)), mv);
                acc0 = vaddq_f32(acc0, vmulq_f32(w0, vld1q_f32(px.add(c))));
                acc1 = vaddq_f32(acc1, vmulq_f32(w1, vld1q_f32(px.add(c + 4))));
                c += LANES;
            }
            hsum8(acc0, acc1)
        };
        for i in full..n {
            s += dq4_1(packed_row, scale_row, min_row, i) * x[i];
        }
        s
    }

    pub fn widen_f16(src: &[u16], out: &mut [f32]) {
        let n = out.len().min(src.len());
        let full = n - n % LANES;
        // SAFETY: reads src[c..c+8) and writes out[c..c+8) with c+8 <=
        // full <= both lengths — in bounds.
        unsafe {
            let (ps, po) = (src.as_ptr(), out.as_mut_ptr());
            let mut c = 0;
            while c < full {
                let (w0, w1) = load_f16x8(ps.add(c));
                vst1q_f32(po.add(c), w0);
                vst1q_f32(po.add(c + 4), w1);
                c += LANES;
            }
        }
        for i in full..n {
            out[i] = f16_to_f32(src[i]);
        }
    }

    pub fn widen_q4(prow: &[u8], srow: &[u16], c0: usize, out: &mut [f32]) {
        let n = out.len();
        // scalar head until the GLOBAL column index is 8-aligned (column
        // windows may start mid-byte / mid-group — matmat shards do)
        let head = ((LANES - c0 % LANES) % LANES).min(n);
        for (k, o) in out[..head].iter_mut().enumerate() {
            *o = dq4(prow, srow, c0 + k);
        }
        let body = (n - head) / LANES * LANES;
        // SAFETY: every chunk covers global columns [g, g+8) with g
        // 8-aligned — 4 packed bytes at g/2 (within the row: g+8 <=
        // c0+n <= cols), one scale group; out writes stay < head+body.
        unsafe {
            let (pp, po) = (prow.as_ptr(), out.as_mut_ptr());
            let eight = vdupq_n_s32(8);
            let mut k = head;
            while k < head + body {
                let g = c0 + k;
                let sv = vdupq_n_f32(f16_to_f32(srow[g / Q4_GROUP]));
                let (q0, q1) = q4_codes_x8(pp, g);
                vst1q_f32(po.add(k), vmulq_f32(sv, vcvtq_f32_s32(vsubq_s32(q0, eight))));
                vst1q_f32(po.add(k + 4), vmulq_f32(sv, vcvtq_f32_s32(vsubq_s32(q1, eight))));
                k += LANES;
            }
        }
        for k in head + body..n {
            out[k] = dq4(prow, srow, c0 + k);
        }
    }

    pub fn widen_q4_1(prow: &[u8], srow: &[u16], mrow: &[u16], c0: usize, out: &mut [f32]) {
        let n = out.len();
        let head = ((LANES - c0 % LANES) % LANES).min(n);
        for (k, o) in out[..head].iter_mut().enumerate() {
            *o = dq4_1(prow, srow, mrow, c0 + k);
        }
        let body = (n - head) / LANES * LANES;
        // SAFETY: same bounds argument as widen_q4.
        unsafe {
            let (pp, po) = (prow.as_ptr(), out.as_mut_ptr());
            let mut k = head;
            while k < head + body {
                let g = c0 + k;
                let grp = g / Q4_GROUP;
                let sv = vdupq_n_f32(f16_to_f32(srow[grp]));
                let mv = vdupq_n_f32(f16_to_f32(mrow[grp]));
                let (q0, q1) = q4_codes_x8(pp, g);
                vst1q_f32(po.add(k), vaddq_f32(vmulq_f32(sv, vcvtq_f32_s32(q0)), mv));
                vst1q_f32(po.add(k + 4), vaddq_f32(vmulq_f32(sv, vcvtq_f32_s32(q1)), mv));
                k += LANES;
            }
        }
        for k in head + body..n {
            out[k] = dq4_1(prow, srow, mrow, c0 + k);
        }
    }

    pub fn axpy_f32(a: f32, row: &[f32], out: &mut [f32]) {
        let n = out.len().min(row.len());
        let full = n - n % LANES;
        // SAFETY: reads row[c..c+8) and read-modify-writes out[c..c+8)
        // with c+8 <= full <= both lengths — in bounds.
        unsafe {
            let (pw, po) = (row.as_ptr(), out.as_mut_ptr());
            let av = vdupq_n_f32(a);
            let mut c = 0;
            while c < full {
                let o0 = vld1q_f32(po.add(c));
                let o1 = vld1q_f32(po.add(c + 4));
                let w0 = vmulq_f32(av, vld1q_f32(pw.add(c)));
                let w1 = vmulq_f32(av, vld1q_f32(pw.add(c + 4)));
                vst1q_f32(po.add(c), vaddq_f32(o0, w0));
                vst1q_f32(po.add(c + 4), vaddq_f32(o1, w1));
                c += LANES;
            }
        }
        for i in full..n {
            out[i] += a * row[i];
        }
    }

    pub fn axpy_f16(a: f32, row: &[u16], out: &mut [f32]) {
        let n = out.len().min(row.len());
        let full = n - n % LANES;
        // SAFETY: same bounds argument as axpy_f32.
        unsafe {
            let (pw, po) = (row.as_ptr(), out.as_mut_ptr());
            let av = vdupq_n_f32(a);
            let mut c = 0;
            while c < full {
                let (w0, w1) = load_f16x8(pw.add(c));
                let o0 = vld1q_f32(po.add(c));
                let o1 = vld1q_f32(po.add(c + 4));
                vst1q_f32(po.add(c), vaddq_f32(o0, vmulq_f32(av, w0)));
                vst1q_f32(po.add(c + 4), vaddq_f32(o1, vmulq_f32(av, w1)));
                c += LANES;
            }
        }
        for i in full..n {
            out[i] += a * f16_to_f32(row[i]);
        }
    }

    pub fn axpy_i8(a: f32, row: &[i8], out: &mut [f32]) {
        let n = out.len().min(row.len());
        let full = n - n % LANES;
        // SAFETY: same bounds argument as axpy_f32.
        unsafe {
            let (pw, po) = (row.as_ptr(), out.as_mut_ptr());
            let av = vdupq_n_f32(a);
            let mut c = 0;
            while c < full {
                let q = vmovl_s8(vld1_s8(pw.add(c)));
                let w0 = vcvtq_f32_s32(vmovl_s16(vget_low_s16(q)));
                let w1 = vcvtq_f32_s32(vmovl_s16(vget_high_s16(q)));
                let o0 = vld1q_f32(po.add(c));
                let o1 = vld1q_f32(po.add(c + 4));
                vst1q_f32(po.add(c), vaddq_f32(o0, vmulq_f32(av, w0)));
                vst1q_f32(po.add(c + 4), vaddq_f32(o1, vmulq_f32(av, w1)));
                c += LANES;
            }
        }
        for i in full..n {
            out[i] += a * row[i] as f32;
        }
    }

    pub fn axpy_q4(a: f32, prow: &[u8], srow: &[u16], c0: usize, out: &mut [f32]) {
        let n = out.len();
        let head = ((LANES - c0 % LANES) % LANES).min(n);
        for (k, o) in out[..head].iter_mut().enumerate() {
            *o += a * dq4(prow, srow, c0 + k);
        }
        let body = (n - head) / LANES * LANES;
        // SAFETY: same bounds argument as widen_q4, plus the
        // read-modify-write of out[k..k+8) stays below head+body <= n.
        unsafe {
            let (pp, po) = (prow.as_ptr(), out.as_mut_ptr());
            let av = vdupq_n_f32(a);
            let eight = vdupq_n_s32(8);
            let mut k = head;
            while k < head + body {
                let g = c0 + k;
                let sv = vdupq_n_f32(f16_to_f32(srow[g / Q4_GROUP]));
                let (q0, q1) = q4_codes_x8(pp, g);
                // a * dq4 = a * (s * (q-8)) — scalar association kept
                let w0 = vmulq_f32(av, vmulq_f32(sv, vcvtq_f32_s32(vsubq_s32(q0, eight))));
                let w1 = vmulq_f32(av, vmulq_f32(sv, vcvtq_f32_s32(vsubq_s32(q1, eight))));
                let o0 = vld1q_f32(po.add(k));
                let o1 = vld1q_f32(po.add(k + 4));
                vst1q_f32(po.add(k), vaddq_f32(o0, w0));
                vst1q_f32(po.add(k + 4), vaddq_f32(o1, w1));
                k += LANES;
            }
        }
        for k in head + body..n {
            out[k] += a * dq4(prow, srow, c0 + k);
        }
    }

    pub fn axpy_q4_1(a: f32, prow: &[u8], srow: &[u16], mrow: &[u16], c0: usize, out: &mut [f32]) {
        let n = out.len();
        let head = ((LANES - c0 % LANES) % LANES).min(n);
        for (k, o) in out[..head].iter_mut().enumerate() {
            *o += a * dq4_1(prow, srow, mrow, c0 + k);
        }
        let body = (n - head) / LANES * LANES;
        // SAFETY: same bounds argument as axpy_q4.
        unsafe {
            let (pp, po) = (prow.as_ptr(), out.as_mut_ptr());
            let av = vdupq_n_f32(a);
            let mut k = head;
            while k < head + body {
                let g = c0 + k;
                let grp = g / Q4_GROUP;
                let sv = vdupq_n_f32(f16_to_f32(srow[grp]));
                let mv = vdupq_n_f32(f16_to_f32(mrow[grp]));
                let (q0, q1) = q4_codes_x8(pp, g);
                let w0 = vmulq_f32(av, vaddq_f32(vmulq_f32(sv, vcvtq_f32_s32(q0)), mv));
                let w1 = vmulq_f32(av, vaddq_f32(vmulq_f32(sv, vcvtq_f32_s32(q1)), mv));
                let o0 = vld1q_f32(po.add(k));
                let o1 = vld1q_f32(po.add(k + 4));
                vst1q_f32(po.add(k), vaddq_f32(o0, w0));
                vst1q_f32(po.add(k + 4), vaddq_f32(o1, w1));
                k += LANES;
            }
        }
        for k in head + body..n {
            out[k] += a * dq4_1(prow, srow, mrow, c0 + k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_table_always_available() {
        let k = kernels_for(SimdBackend::Scalar).expect("scalar is always available");
        assert_eq!(k.backend, SimdBackend::Scalar);
        assert!(available(SimdBackend::Scalar));
    }

    #[test]
    fn detect_is_available_and_selectable() {
        let best = detect();
        assert!(available(best), "auto-detected backend must be runnable");
        assert_eq!(select(None).unwrap(), best);
        assert_eq!(active(), best);
        assert_eq!(kernels().backend, best);
    }

    #[test]
    fn forcing_unavailable_backend_errors() {
        for b in [SimdBackend::Neon, SimdBackend::Avx2] {
            if !available(b) {
                assert!(select(Some(b)).is_err(), "{} must be refused", b.name());
                assert!(kernels_for(b).is_none());
            }
        }
    }

    #[test]
    fn forcing_scalar_always_works() {
        // NOTE: mutates the global selection, but every backend is
        // bit-identical, so concurrent kernel users can't observe it.
        assert_eq!(select(Some(SimdBackend::Scalar)).unwrap(), SimdBackend::Scalar);
        assert_eq!(kernels().backend, SimdBackend::Scalar);
        // restore auto for any test running after us
        select(None).unwrap();
    }

    #[test]
    fn backend_ids_round_trip() {
        for b in [SimdBackend::Scalar, SimdBackend::Neon, SimdBackend::Avx2] {
            assert_eq!(SimdBackend::from_u8(b.as_u8()), Some(b));
        }
        assert_eq!(SimdBackend::from_u8(UNSET), None);
    }
}
