//! Fused matvec kernels — the hot path of single-slot token generation
//! and the per-slot REFERENCE the batched/parallel paths are tested
//! against.
//!
//! # Dtype support matrix
//!
//! | kernel                  | f32 | f16 | i8 (scale)   | q4/q4_1 (group) | packed |
//! |-------------------------|-----|-----|--------------|-----------------|--------|
//! | [`matvec_in_out`]       | yes | yes | per-column   | yes             | —      |
//! | [`matvec_rows`]         | yes | yes | per-row      | yes             | —      |
//! | [`matvec_rows_indexed`] | yes | yes | per-row      | yes             | —      |
//! | [`accum_rows_indexed`]  | yes | yes | per-column   | yes             | —      |
//! | [`ShadowView::matvec`]  | —   | —   | —            | —               | 1/4-bit|
//!
//! The q4/q4_1 arms dequantize in-register per element via
//! [`crate::tensor::q4`] (group scales applied inline — no end-of-loop
//! scale fold like i8, so `out` may always carry a residual) and are
//! bit-identical to running the f32 arm on the dequantized matrix.
//!
//! # Kernel dispatch
//!
//! Each entry point resolves the active [`crate::tensor::simd::Kernels`]
//! table ONCE, then runs its dtype arm through the table's `fn` pointers
//! (dot / axpy per row).  The dispatch is a pure performance knob:
//! every backend is bit-identical to the scalar reference (the LANES=8
//! accumulator dots below), so the determinism story is unchanged.
//!
//! # Determinism
//!
//! Every kernel is a fixed sequence of f32 operations (ascending weight
//! rows, the LANES accumulator-array dots) — no runtime reassociation, so
//! repeated calls are bit-identical, and the multi-vector `matmat`
//! kernels (serial AND pool-sharded — one entry point, `Par`-driven)
//! reproduce these results exactly per slot.
//!
//! Inner loops are shaped for LLVM auto-vectorization on the scalar
//! backend: contiguous slices, no bounds checks in the loop body
//! (iterator zips), f32 accumulation.  The int8 kernels fold
//! dequantization into the loop (paper §4: fused dequant+matvec; no
//! materialized f32/f16 weight copy).

use crate::tensor::q4::{q4_groups, q4_row_packed_bytes};
use crate::tensor::{simd, Mat};
use crate::util::f16::f16_to_f32_fast as f16_to_f32;

/// `out[j] += sum_i x[i] * w[i][j]` for `(in, out)`-layout `w`.
/// `out` must be zeroed (or carry an accumulator) by the caller.
///
/// `acc` is caller-owned scratch used only by the i8 arm (resized to
/// `cols` there, untouched otherwise) — hot-loop callers keep one in
/// their `Scratch` so this stays allocation-free as documented.
pub fn matvec_in_out(x: &[f32], w: &Mat, out: &mut [f32], acc: &mut Vec<f32>) {
    let (rows, cols) = (w.rows(), w.cols());
    assert_eq!(x.len(), rows);
    assert_eq!(out.len(), cols);
    let k = simd::kernels();
    match w {
        Mat::F32 { data, .. } => {
            for (i, &xi) in x.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                (k.axpy_f32)(xi, &data[i * cols..(i + 1) * cols], out);
            }
        }
        Mat::F16 { data, .. } => {
            for (i, &xi) in x.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                (k.axpy_f16)(xi, &data[i * cols..(i + 1) * cols], out);
            }
        }
        Mat::I8 { data, scale, .. } => {
            // `out` may carry a residual accumulator, so the per-column
            // scale must apply only to THIS product: accumulate unscaled
            // in the caller's scratch, then fold scale while adding.
            acc.clear();
            acc.resize(cols, 0.0);
            for (i, &xi) in x.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                (k.axpy_i8)(xi, &data[i * cols..(i + 1) * cols], acc);
            }
            for ((o, &a), &s) in out.iter_mut().zip(acc.iter()).zip(scale) {
                *o += a * s;
            }
        }
        Mat::Q4 { data, scale, .. } => {
            // group scales are per (row, group) of THIS product, so they
            // fold in per element — `out` may carry a residual freely
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for (i, &xi) in x.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let prow = &data[i * prb..(i + 1) * prb];
                let srow = &scale[i * ng..(i + 1) * ng];
                (k.axpy_q4)(xi, prow, srow, 0, out);
            }
        }
        Mat::Q41 { data, scale, min, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for (i, &xi) in x.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let prow = &data[i * prb..(i + 1) * prb];
                let srow = &scale[i * ng..(i + 1) * ng];
                let mrow = &min[i * ng..(i + 1) * ng];
                (k.axpy_q4_1)(xi, prow, srow, mrow, 0, out);
            }
        }
    }
}

/// `out[j] = dot(w[j], x)` for `(out, in)`-layout `w` (row per output).
pub fn matvec_rows(w: &Mat, x: &[f32], out: &mut [f32]) {
    let (rows, cols) = (w.rows(), w.cols());
    assert_eq!(x.len(), cols);
    assert_eq!(out.len(), rows);
    let k = simd::kernels();
    match w {
        Mat::F32 { data, .. } => {
            for (j, o) in out.iter_mut().enumerate() {
                *o = (k.dot_f32)(&data[j * cols..(j + 1) * cols], x);
            }
        }
        Mat::F16 { data, .. } => {
            for (j, o) in out.iter_mut().enumerate() {
                *o = (k.dot_f16)(&data[j * cols..(j + 1) * cols], x);
            }
        }
        Mat::I8 { data, scale, .. } => {
            for (j, o) in out.iter_mut().enumerate() {
                *o = scale[j] * (k.dot_i8)(&data[j * cols..(j + 1) * cols], x);
            }
        }
        Mat::Q4 { data, scale, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for (j, o) in out.iter_mut().enumerate() {
                *o = (k.dot_q4)(&data[j * prb..(j + 1) * prb], &scale[j * ng..(j + 1) * ng], x);
            }
        }
        Mat::Q41 { data, scale, min, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for (j, o) in out.iter_mut().enumerate() {
                *o = (k.dot_q4_1)(
                    &data[j * prb..(j + 1) * prb],
                    &scale[j * ng..(j + 1) * ng],
                    &min[j * ng..(j + 1) * ng],
                    x,
                );
            }
        }
    }
}

/// Sparse row-layout matvec: compute only `idx`-selected outputs.
/// `out[k] = dot(w[idx[k]], x)` — the §3.2 "load only predicted neurons"
/// compute path (the *memory accounting* for those rows is done by the
/// caller, which knows whether rows were already resident).
pub fn matvec_rows_indexed(w: &Mat, idx: &[u32], x: &[f32], out: &mut [f32]) {
    let cols = w.cols();
    assert_eq!(x.len(), cols);
    assert_eq!(out.len(), idx.len());
    let k = simd::kernels();
    match w {
        Mat::F32 { data, .. } => {
            for (o, &j) in out.iter_mut().zip(idx) {
                let j = j as usize;
                *o = (k.dot_f32)(&data[j * cols..(j + 1) * cols], x);
            }
        }
        Mat::F16 { data, .. } => {
            for (o, &j) in out.iter_mut().zip(idx) {
                let j = j as usize;
                *o = (k.dot_f16)(&data[j * cols..(j + 1) * cols], x);
            }
        }
        Mat::I8 { data, scale, .. } => {
            for (o, &j) in out.iter_mut().zip(idx) {
                let j = j as usize;
                *o = scale[j] * (k.dot_i8)(&data[j * cols..(j + 1) * cols], x);
            }
        }
        Mat::Q4 { data, scale, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for (o, &j) in out.iter_mut().zip(idx) {
                let j = j as usize;
                *o = (k.dot_q4)(&data[j * prb..(j + 1) * prb], &scale[j * ng..(j + 1) * ng], x);
            }
        }
        Mat::Q41 { data, scale, min, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for (o, &j) in out.iter_mut().zip(idx) {
                let j = j as usize;
                *o = (k.dot_q4_1)(
                    &data[j * prb..(j + 1) * prb],
                    &scale[j * ng..(j + 1) * ng],
                    &min[j * ng..(j + 1) * ng],
                    x,
                );
            }
        }
    }
}

/// Sparse accumulate of selected `(in,out)`-layout rows:
/// `out[:] += sum_k h[k] * w[idx[k]][:]` — the W_v half of the sparse FFN
/// (rows of `wv` are per-neuron, layout (F, D)).
pub fn accum_rows_indexed(w: &Mat, idx: &[u32], h: &[f32], out: &mut [f32]) {
    let cols = w.cols();
    assert_eq!(out.len(), cols);
    assert_eq!(h.len(), idx.len());
    let k = simd::kernels();
    match w {
        Mat::F32 { data, .. } => {
            for (&hk, &j) in h.iter().zip(idx) {
                if hk == 0.0 {
                    continue;
                }
                (k.axpy_f32)(hk, &data[j as usize * cols..(j as usize + 1) * cols], out);
            }
        }
        Mat::F16 { data, .. } => {
            for (&hk, &j) in h.iter().zip(idx) {
                if hk == 0.0 {
                    continue;
                }
                (k.axpy_f16)(hk, &data[j as usize * cols..(j as usize + 1) * cols], out);
            }
        }
        Mat::I8 { data, scale, .. } => {
            // (in,out) layout: scale is per-column of the ORIGINAL w, i.e.
            // per element of `out`; accumulate unscaled then scale once is
            // wrong here because different rows share columns — scale is
            // per-out-column so it factors out of the row sum:
            for (&hk, &j) in h.iter().zip(idx) {
                if hk == 0.0 {
                    continue;
                }
                (k.axpy_i8)(hk, &data[j as usize * cols..(j as usize + 1) * cols], out);
            }
            for (o, &s) in out.iter_mut().zip(scale) {
                *o *= s;
            }
        }
        Mat::Q4 { data, scale, .. } => {
            // group scale applies inline (unlike i8's per-column fold):
            // the scale belongs to the (row, group) pair, not the column
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for (&hk, &j) in h.iter().zip(idx) {
                if hk == 0.0 {
                    continue;
                }
                let j = j as usize;
                let prow = &data[j * prb..(j + 1) * prb];
                let srow = &scale[j * ng..(j + 1) * ng];
                (k.axpy_q4)(hk, prow, srow, 0, out);
            }
        }
        Mat::Q41 { data, scale, min, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for (&hk, &j) in h.iter().zip(idx) {
                if hk == 0.0 {
                    continue;
                }
                let j = j as usize;
                let prow = &data[j * prb..(j + 1) * prb];
                let srow = &scale[j * ng..(j + 1) * ng];
                let mrow = &min[j * ng..(j + 1) * ng];
                (k.axpy_q4_1)(hk, prow, srow, mrow, 0, out);
            }
        }
    }
}

/// Sub-byte packing of a [`ShadowView`] — which decode the matvec runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ShadowKind {
    /// 1-bit sign matrix (§3.2, Eq. 4): bit b of `packed[i/8][j]` is the
    /// sign of `w[i][j]` (1 -> +1).
    Bits,
    /// 4-bit offset-binary (§B.4 / Figure 9): row 2i in the LOW nibble,
    /// row 2i+1 in the HIGH nibble, each storing q+8 with q in [-7, 7]
    /// (export.py `nibble_quant`).
    Nib4,
}

/// Borrowed view of a sub-byte shadow matrix for the quantized sparsity
/// predictor — the unified surface that replaced the `bit_matvec` /
/// `nib4_matvec` free functions.  Layout is `(packed-rows, out)` bytes
/// with a per-output-column scale; construct with [`ShadowView::bits`]
/// or [`ShadowView::nib4`], then call [`ShadowView::matvec`] per token.
///
/// Shadow matvecs are deliberately NOT routed through the SIMD kernel
/// table: the predictor is a few percent of a block's work, and the
/// bit/nibble unpack loops below autovectorize well enough.
pub struct ShadowView<'a> {
    kind: ShadowKind,
    packed: &'a [u8],
    scale: &'a [f32],
    in_dim: usize,
}

impl<'a> ShadowView<'a> {
    /// View a 1-bit sign matrix: `(ceil(in/8), out)` packed bytes.
    pub fn bits(packed: &'a [u8], scale: &'a [f32], in_dim: usize) -> Self {
        assert_eq!(packed.len(), in_dim.div_ceil(8) * scale.len());
        ShadowView { kind: ShadowKind::Bits, packed, scale, in_dim }
    }

    /// View a 4-bit nibble matrix: `(ceil(in/2), out)` packed bytes.
    pub fn nib4(packed: &'a [u8], scale: &'a [f32], in_dim: usize) -> Self {
        assert_eq!(packed.len(), in_dim.div_ceil(2) * scale.len());
        ShadowView { kind: ShadowKind::Nib4, packed, scale, in_dim }
    }

    /// `out[j] = scale[j] * sum_i x[i] * q[i][j]` with the sub-byte
    /// decode folded into the loop (`out` is overwritten, not
    /// accumulated — the predictor score is a fresh vector per token).
    pub fn matvec(&self, x: &[f32], out: &mut [f32]) {
        let (in_dim, out_dim) = (self.in_dim, self.scale.len());
        assert_eq!(x.len(), in_dim);
        assert_eq!(out.len(), out_dim);
        out.fill(0.0);
        let total: f32 = x.iter().sum();
        match self.kind {
            ShadowKind::Bits => {
                // sum_i (+-x_i) = 2 * sum_{i: bit set} x_i - sum_i x_i
                for i in 0..in_dim {
                    let xi = x[i];
                    if xi == 0.0 {
                        continue;
                    }
                    let byte_row = &self.packed[(i / 8) * out_dim..(i / 8 + 1) * out_dim];
                    let bit = 1u8 << (i % 8);
                    for (o, &b) in out.iter_mut().zip(byte_row) {
                        // branchless select: add xi where the sign bit is set
                        *o += if b & bit != 0 { xi } else { 0.0 };
                    }
                }
                for (o, &s) in out.iter_mut().zip(self.scale) {
                    *o = s * (2.0 * *o - total);
                }
            }
            ShadowKind::Nib4 => {
                // offset-binary: q = nib - 8, so sum x_i*(nib_i - 8)
                //   = sum x_i*nib_i - 8*sum x_i  (fold the -8 out of the loop)
                for i2 in 0..in_dim.div_ceil(2) {
                    let x_lo = x[2 * i2];
                    let x_hi = if 2 * i2 + 1 < in_dim { x[2 * i2 + 1] } else { 0.0 };
                    let row = &self.packed[i2 * out_dim..(i2 + 1) * out_dim];
                    if x_lo == 0.0 && x_hi == 0.0 {
                        continue;
                    }
                    for (o, &b) in out.iter_mut().zip(row) {
                        *o += x_lo * (b & 0xF) as f32 + x_hi * (b >> 4) as f32;
                    }
                }
                for (o, &s) in out.iter_mut().zip(self.scale) {
                    *o = s * (*o - 8.0 * total);
                }
            }
        }
    }
}

// Dot-product reductions: rustc cannot reassociate float adds, so a scalar
// accumulator serializes the loop and blocks SIMD.  The accumulator-ARRAY
// form below maps the 8 partial sums onto one vector register, which LLVM
// reliably turns into packed FMAs (§Perf L3 iteration 2: 4-9x on dots).
// These are the scalar REFERENCE the `tensor::simd` backends replicate
// bit-for-bit: same per-lane products, same 8-lane reduce order, same
// scalar tail.
const LANES: usize = 8;

#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut acc = [0f32; LANES];
    for (ca, cb) in a[..n].chunks_exact(LANES).zip(b[..n].chunks_exact(LANES)) {
        for k in 0..LANES {
            acc[k] += ca[k] * cb[k];
        }
    }
    let rem = n - n % LANES;
    let mut s: f32 = acc.iter().sum();
    for i in rem..n {
        s += a[i] * b[i];
    }
    s
}

#[inline]
pub fn dot_f16(a: &[u16], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut acc = [0f32; LANES];
    for (ca, cb) in a[..n].chunks_exact(LANES).zip(b[..n].chunks_exact(LANES)) {
        for k in 0..LANES {
            acc[k] += f16_to_f32(ca[k]) * cb[k];
        }
    }
    let rem = n - n % LANES;
    let mut s: f32 = acc.iter().sum();
    for i in rem..n {
        s += f16_to_f32(a[i]) * b[i];
    }
    s
}

#[inline]
pub fn dot_i8(a: &[i8], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut acc = [0f32; LANES];
    for (ca, cb) in a[..n].chunks_exact(LANES).zip(b[..n].chunks_exact(LANES)) {
        for k in 0..LANES {
            acc[k] += ca[k] as f32 * cb[k];
        }
    }
    let rem = n - n % LANES;
    let mut s: f32 = acc.iter().sum();
    for i in rem..n {
        s += a[i] as f32 * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn naive(x: &[f32], w: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut out = vec![0f32; cols];
        for i in 0..rows {
            for j in 0..cols {
                out[j] += x[i] * w[i * cols + j];
            }
        }
        out
    }

    #[test]
    fn in_out_f32_matches_naive() {
        let mut r = XorShift::new(1);
        let (rows, cols) = (13, 7);
        let w: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
        let x: Vec<f32> = (0..rows).map(|_| r.normal()).collect();
        let mut out = vec![0f32; cols];
        matvec_in_out(&x, &Mat::from_f32(rows, cols, w.clone()), &mut out, &mut Vec::new());
        let want = naive(&x, &w, rows, cols);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn rows_layout_matches_transpose() {
        let mut r = XorShift::new(2);
        let (rows, cols) = (9, 17);
        let w: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
        let x: Vec<f32> = (0..cols).map(|_| r.normal()).collect();
        let mut out = vec![0f32; rows];
        matvec_rows(&Mat::from_f32(rows, cols, w.clone()), &x, &mut out);
        for j in 0..rows {
            let want = dot_f32(&w[j * cols..(j + 1) * cols], &x);
            assert!((out[j] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn i8_in_out_respects_residual_accumulator() {
        // regression: the per-column scale must not touch pre-existing
        // accumulator content (residual connections pass `out` with x).
        let w = Mat::I8 {
            rows: 2,
            cols: 2,
            data: vec![100, 0, 0, 100],
            scale: vec![0.01, 0.01],
        };
        let x = vec![1.0f32, 2.0];
        let mut out = vec![10.0f32, 20.0]; // residual
        matvec_in_out(&x, &w, &mut out, &mut Vec::new());
        assert_eq!(out, vec![11.0, 22.0]);
    }

    #[test]
    fn f16_close_to_f32() {
        let mut r = XorShift::new(3);
        let (rows, cols) = (32, 24);
        let w: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
        let x: Vec<f32> = (0..rows).map(|_| r.normal()).collect();
        let mut out32 = vec![0f32; cols];
        let mut out16 = vec![0f32; cols];
        matvec_in_out(&x, &Mat::from_f32(rows, cols, w.clone()), &mut out32, &mut Vec::new());
        matvec_in_out(&x, &Mat::f32_to_f16_mat(rows, cols, &w), &mut out16, &mut Vec::new());
        for (a, b) in out32.iter().zip(&out16) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn indexed_matches_dense_rows() {
        let mut r = XorShift::new(4);
        let (rows, cols) = (20, 11);
        let w: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
        let x: Vec<f32> = (0..cols).map(|_| r.normal()).collect();
        let m = Mat::from_f32(rows, cols, w);
        let mut full = vec![0f32; rows];
        matvec_rows(&m, &x, &mut full);
        let idx = vec![3u32, 0, 19, 7];
        let mut sparse = vec![0f32; idx.len()];
        matvec_rows_indexed(&m, &idx, &x, &mut sparse);
        for (k, &j) in idx.iter().enumerate() {
            assert_eq!(sparse[k], full[j as usize]);
        }
    }

    #[test]
    fn accum_rows_matches_masked_dense() {
        let mut r = XorShift::new(5);
        let (rows, cols) = (16, 9); // (F, D)
        let w: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
        let m = Mat::from_f32(rows, cols, w.clone());
        let idx = vec![2u32, 5, 11];
        let h = vec![0.5f32, -1.0, 2.0];
        let mut out = vec![0f32; cols];
        accum_rows_indexed(&m, &idx, &h, &mut out);
        let mut want = vec![0f32; cols];
        for (k, &j) in idx.iter().enumerate() {
            for c in 0..cols {
                want[c] += h[k] * w[j as usize * cols + c];
            }
        }
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn q4_kernels_bitwise_match_dequantized_dense() {
        // the q4 arms' contract: BIT-identical to running the f32 arm on
        // the dequantized matrix, across group-ragged shapes
        let mut r = XorShift::new(10);
        for &(rows, cols) in &[(13usize, 32usize), (9, 40), (7, 33), (5, 7)] {
            let w: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
            let quants =
                [Mat::quantize_q4_mat(rows, cols, &w), Mat::quantize_q4_1_mat(rows, cols, &w)];
            for q in quants {
                let dense = Mat::from_f32(rows, cols, q.to_f32_vec());
                // (in,out) with a residual accumulator
                let x: Vec<f32> = (0..rows).map(|_| r.normal()).collect();
                let residual: Vec<f32> = (0..cols).map(|_| r.normal()).collect();
                let (mut got, mut want) = (residual.clone(), residual.clone());
                matvec_in_out(&x, &q, &mut got, &mut Vec::new());
                matvec_in_out(&x, &dense, &mut want, &mut Vec::new());
                assert_eq!(got, want, "in_out {rows}x{cols}");
                // row-per-output
                let xc: Vec<f32> = (0..cols).map(|_| r.normal()).collect();
                let (mut got, mut want) = (vec![0f32; rows], vec![0f32; rows]);
                matvec_rows(&q, &xc, &mut got);
                matvec_rows(&dense, &xc, &mut want);
                assert_eq!(got, want, "rows {rows}x{cols}");
                // indexed subset
                let idx: Vec<u32> = vec![0, rows as u32 - 1, rows as u32 / 2];
                let (mut got, mut want) = (vec![0f32; idx.len()], vec![0f32; idx.len()]);
                matvec_rows_indexed(&q, &idx, &xc, &mut got);
                matvec_rows_indexed(&dense, &idx, &xc, &mut want);
                assert_eq!(got, want, "rows_indexed {rows}x{cols}");
                // sparse accumulate
                let h = vec![0.5f32, -1.25, 2.0];
                let (mut got, mut want) = (vec![0f32; cols], vec![0f32; cols]);
                accum_rows_indexed(&q, &idx, &h, &mut got);
                accum_rows_indexed(&dense, &idx, &h, &mut want);
                assert_eq!(got, want, "accum {rows}x{cols}");
            }
        }
    }

    #[test]
    fn nib4_shadow_matches_dequant_dense() {
        let mut r = XorShift::new(9);
        for &(in_dim, out_dim) in &[(10usize, 6usize), (7, 4), (16, 13)] {
            // random q in [-7, 7], per-column scale
            let q: Vec<i8> = (0..in_dim * out_dim)
                .map(|_| ((r.next_u64() % 15) as i8) - 7)
                .collect();
            let scale: Vec<f32> = (0..out_dim).map(|_| r.next_f32() + 0.05).collect();
            let x: Vec<f32> = (0..in_dim).map(|_| r.normal()).collect();
            // pack: row 2i low nibble, row 2i+1 high nibble (pad q=0 -> 8)
            let half = in_dim.div_ceil(2);
            let mut packed = vec![0u8; half * out_dim];
            for i2 in 0..half {
                for j in 0..out_dim {
                    let lo = (q[(2 * i2) * out_dim + j] + 8) as u8;
                    let hi = if 2 * i2 + 1 < in_dim {
                        (q[(2 * i2 + 1) * out_dim + j] + 8) as u8
                    } else {
                        8
                    };
                    packed[i2 * out_dim + j] = lo | (hi << 4);
                }
            }
            let mut out = vec![0f32; out_dim];
            ShadowView::nib4(&packed, &scale, in_dim).matvec(&x, &mut out);
            for j in 0..out_dim {
                let mut want = 0f32;
                for i in 0..in_dim {
                    want += x[i] * q[i * out_dim + j] as f32;
                }
                want *= scale[j];
                assert!((out[j] - want).abs() < 1e-3, "{} vs {}", out[j], want);
            }
        }
    }

    #[test]
    fn bit_shadow_matches_sign_dense() {
        let mut r = XorShift::new(6);
        let (in_dim, out_dim): (usize, usize) = (19, 13);
        // random sign matrix
        let signs: Vec<bool> = (0..in_dim * out_dim).map(|_| r.next_f32() < 0.5).collect();
        let scale: Vec<f32> = (0..out_dim).map(|_| r.next_f32() + 0.1).collect();
        let x: Vec<f32> = (0..in_dim).map(|_| r.normal()).collect();
        // pack: bit i%8 of packed[i/8][j]
        let mut packed = vec![0u8; in_dim.div_ceil(8) * out_dim];
        for i in 0..in_dim {
            for j in 0..out_dim {
                if signs[i * out_dim + j] {
                    packed[(i / 8) * out_dim + j] |= 1 << (i % 8);
                }
            }
        }
        let mut out = vec![0f32; out_dim];
        ShadowView::bits(&packed, &scale, in_dim).matvec(&x, &mut out);
        for j in 0..out_dim {
            let mut want = 0f32;
            for i in 0..in_dim {
                want += if signs[i * out_dim + j] { x[i] } else { -x[i] };
            }
            want *= scale[j];
            assert!((out[j] - want).abs() < 1e-3, "{} vs {}", out[j], want);
        }
    }
}
