//! Owned weight matrices in their storage precision.

use anyhow::{bail, Result};

use crate::tensor::q4::{
    dequant_row_q4, dequant_row_q4_1, q4_groups, q4_row_packed_bytes, quantize_q4, quantize_q4_1,
};
use crate::util::f16::{f16_to_f32, f32_to_f16};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    F16,
    I8,
    U8,
    I32,
    /// Group-quantized 4-bit, symmetric: 32-element groups along the last
    /// axis, per-group f16 scale in a `<name>.scale` sibling tensor,
    /// packed two codes per byte (see [`crate::tensor::q4`]).
    Q4,
    /// Group-quantized 4-bit with per-group minimum (`<name>.min`
    /// sibling): asymmetric codes for all-positive tensors.
    Q41,
}

impl DType {
    /// Bytes per element for the scalar dtypes.
    ///
    /// # Panics
    ///
    /// Panics on the sub-byte dtypes ([`DType::Q4`] / [`DType::Q41`]),
    /// whose payload size is not per-element — use [`DType::bytes_for`].
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 => 2,
            DType::I8 | DType::U8 => 1,
            DType::Q4 | DType::Q41 => {
                unreachable!("sub-byte dtype has no per-element size; use bytes_for")
            }
        }
    }

    /// Total payload bytes for `shape`, or `None` when the shape is not
    /// representable for this dtype: size overflow, or a sub-byte dtype
    /// with rank != 2 (the pack layout is defined over `(rows, cols)`).
    pub fn bytes_for(self, shape: &[usize]) -> Option<u64> {
        match self {
            DType::Q4 | DType::Q41 => {
                let [rows, cols] = *shape else { return None };
                (rows as u64).checked_mul(q4_row_packed_bytes(cols) as u64)
            }
            _ => {
                let mut n: u64 = 1;
                for &d in shape {
                    n = n.checked_mul(d as u64)?;
                }
                n.checked_mul(self.size() as u64)
            }
        }
    }

    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::F16,
            2 => DType::I8,
            3 => DType::U8,
            4 => DType::I32,
            5 => DType::Q4,
            6 => DType::Q41,
            _ => bail!("unknown dtype code {c}"),
        })
    }
}

/// A 2-D weight matrix (rows x cols), row-major, owned.
///
/// `I8` carries the per-output scale vector (length = the *logical output
/// dimension*: `cols` for in-out layout, `rows` for row-per-output layout —
/// the consumer knows which).
///
/// `Q4` / `Q41` carry the group-quantized payload (`rows *
/// cols.div_ceil(2)` packed bytes) plus the per-(row, group) f16 parameter
/// bits — `rows * cols.div_ceil(32)` scale entries, and for `Q41` an
/// equally shaped min array (see [`crate::tensor::q4`] for the layout and
/// the bit-exactness contract).
#[derive(Clone, Debug, PartialEq)]
pub enum Mat {
    F32 { rows: usize, cols: usize, data: Vec<f32> },
    F16 { rows: usize, cols: usize, data: Vec<u16> },
    I8 { rows: usize, cols: usize, data: Vec<i8>, scale: Vec<f32> },
    Q4 { rows: usize, cols: usize, data: Vec<u8>, scale: Vec<u16> },
    Q41 { rows: usize, cols: usize, data: Vec<u8>, scale: Vec<u16>, min: Vec<u16> },
}

impl Mat {
    pub fn rows(&self) -> usize {
        match self {
            Mat::F32 { rows, .. }
            | Mat::F16 { rows, .. }
            | Mat::I8 { rows, .. }
            | Mat::Q4 { rows, .. }
            | Mat::Q41 { rows, .. } => *rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Mat::F32 { cols, .. }
            | Mat::F16 { cols, .. }
            | Mat::I8 { cols, .. }
            | Mat::Q4 { cols, .. }
            | Mat::Q41 { cols, .. } => *cols,
        }
    }

    /// Stored bytes (the memory-footprint accounting unit).
    pub fn nbytes(&self) -> u64 {
        match self {
            Mat::F32 { data, .. } => 4 * data.len() as u64,
            Mat::F16 { data, .. } => 2 * data.len() as u64,
            Mat::I8 { data, scale, .. } => data.len() as u64 + 4 * scale.len() as u64,
            Mat::Q4 { data, scale, .. } => data.len() as u64 + 2 * scale.len() as u64,
            Mat::Q41 { data, scale, min, .. } => {
                data.len() as u64 + 2 * scale.len() as u64 + 2 * min.len() as u64
            }
        }
    }

    /// Bytes of a single row in storage precision (sparse-load accounting).
    pub fn row_bytes(&self) -> u64 {
        let c = self.cols();
        match self {
            Mat::F32 { .. } => 4 * c as u64,
            Mat::F16 { .. } => 2 * c as u64,
            Mat::I8 { .. } => c as u64 + 4, // + its scale entry
            Mat::Q4 { .. } => (q4_row_packed_bytes(c) + 2 * q4_groups(c)) as u64,
            Mat::Q41 { .. } => (q4_row_packed_bytes(c) + 4 * q4_groups(c)) as u64,
        }
    }

    pub fn from_f32(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat::F32 { rows, cols, data }
    }

    pub fn f32_to_f16_mat(rows: usize, cols: usize, data: &[f32]) -> Self {
        Mat::F16 {
            rows,
            cols,
            data: data.iter().map(|&x| f32_to_f16(x)).collect(),
        }
    }

    /// Group-quantize an f32 matrix to the symmetric Q4 format.
    pub fn quantize_q4_mat(rows: usize, cols: usize, data: &[f32]) -> Self {
        let (packed, scale) = quantize_q4(rows, cols, data);
        Mat::Q4 { rows, cols, data: packed, scale }
    }

    /// Group-quantize an f32 matrix to the asymmetric Q4_1 format.
    pub fn quantize_q4_1_mat(rows: usize, cols: usize, data: &[f32]) -> Self {
        let (packed, scale, min) = quantize_q4_1(rows, cols, data);
        Mat::Q41 { rows, cols, data: packed, scale, min }
    }

    /// Decode one row to f32 into `out` (row-per-output layout consumers).
    /// For `I8`, `scale_idx` selects the per-output scale (usually == row).
    pub fn decode_row(&self, row: usize, out: &mut [f32]) {
        let c = self.cols();
        debug_assert!(out.len() == c);
        match self {
            Mat::F32 { data, .. } => out.copy_from_slice(&data[row * c..(row + 1) * c]),
            Mat::F16 { data, .. } => {
                for (o, &h) in out.iter_mut().zip(&data[row * c..(row + 1) * c]) {
                    *o = f16_to_f32(h);
                }
            }
            Mat::I8 { data, scale, .. } => {
                if scale.len() == c {
                    // per-column scale ((in,out)-layout tensors, e.g. emb)
                    for ((o, &q), &s) in out
                        .iter_mut()
                        .zip(&data[row * c..(row + 1) * c])
                        .zip(scale.iter())
                    {
                        *o = q as f32 * s;
                    }
                } else {
                    // per-row scale (row-per-output tensors, e.g. head)
                    let s = scale[row];
                    for (o, &q) in out.iter_mut().zip(&data[row * c..(row + 1) * c]) {
                        *o = q as f32 * s;
                    }
                }
            }
            Mat::Q4 { data, scale, .. } => {
                let (prb, ng) = (q4_row_packed_bytes(c), q4_groups(c));
                dequant_row_q4(
                    &data[row * prb..(row + 1) * prb],
                    &scale[row * ng..(row + 1) * ng],
                    out,
                );
            }
            Mat::Q41 { data, scale, min, .. } => {
                let (prb, ng) = (q4_row_packed_bytes(c), q4_groups(c));
                dequant_row_q4_1(
                    &data[row * prb..(row + 1) * prb],
                    &scale[row * ng..(row + 1) * ng],
                    &min[row * ng..(row + 1) * ng],
                    out,
                );
            }
        }
    }

    /// Full decode to f32 (used when uploading to the XLA backend).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        match self {
            Mat::F32 { data, .. } => data.clone(),
            Mat::F16 { data, .. } => data.iter().map(|&h| f16_to_f32(h)).collect(),
            Mat::I8 { rows, cols, data, scale } => {
                // scale is per-output; output dim may be rows or cols.  For
                // in-out layout scale.len() == cols; for row layout == rows.
                let mut out = vec![0f32; rows * cols];
                if scale.len() == *cols {
                    for r in 0..*rows {
                        for c in 0..*cols {
                            out[r * cols + c] = data[r * cols + c] as f32 * scale[c];
                        }
                    }
                } else {
                    debug_assert_eq!(scale.len(), *rows);
                    for r in 0..*rows {
                        let s = scale[r];
                        for c in 0..*cols {
                            out[r * cols + c] = data[r * cols + c] as f32 * s;
                        }
                    }
                }
                out
            }
            Mat::Q4 { rows, cols, .. } | Mat::Q41 { rows, cols, .. } => {
                let mut out = vec![0f32; rows * cols];
                for r in 0..*rows {
                    self.decode_row(r, &mut out[r * cols..(r + 1) * cols]);
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_decode_f16() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let m = Mat::f32_to_f16_mat(2, 3, &data);
        let mut row = vec![0f32; 3];
        m.decode_row(1, &mut row);
        assert_eq!(row, vec![4.0, 5.0, 6.0]);
        assert_eq!(m.nbytes(), 12);
        assert_eq!(m.row_bytes(), 6);
    }

    #[test]
    fn i8_decode_row_per_row_scale() {
        // non-square, scale.len() == rows -> per-row semantics (head/wk_t)
        let m = Mat::I8 {
            rows: 2,
            cols: 3,
            data: vec![10, -20, 30, 40, 50, 60],
            scale: vec![0.1, 0.5],
        };
        let mut row = vec![0f32; 3];
        m.decode_row(0, &mut row);
        assert_eq!(row, vec![1.0, -2.0, 3.0]);
        m.decode_row(1, &mut row);
        assert_eq!(row, vec![20.0, 25.0, 30.0]);
    }

    #[test]
    fn i8_decode_row_per_column_scale() {
        // scale.len() == cols -> per-column semantics (emb, square mats)
        let m = Mat::I8 {
            rows: 2,
            cols: 2,
            data: vec![10, -20, 30, 40],
            scale: vec![0.1, 0.5],
        };
        let mut row = vec![0f32; 2];
        m.decode_row(0, &mut row);
        assert_eq!(row, vec![1.0, -10.0]);
        m.decode_row(1, &mut row);
        assert_eq!(row, vec![3.0, 20.0]);
    }

    #[test]
    fn to_f32_per_column_scale() {
        let m = Mat::I8 {
            rows: 1,
            cols: 2,
            data: vec![100, 50],
            scale: vec![0.01, 0.02],
        };
        assert_eq!(m.to_f32_vec(), vec![1.0, 1.0]);
    }

    #[test]
    fn q4_decode_row_matches_to_f32_vec() {
        let data: Vec<f32> = (0..3 * 40).map(|i| (i as f32 * 0.37).sin()).collect();
        for m in [Mat::quantize_q4_mat(3, 40, &data), Mat::quantize_q4_1_mat(3, 40, &data)] {
            let full = m.to_f32_vec();
            let mut row = vec![0f32; 40];
            for r in 0..3 {
                m.decode_row(r, &mut row);
                assert_eq!(&row[..], &full[r * 40..(r + 1) * 40]);
            }
        }
    }

    #[test]
    fn q4_byte_accounting_is_packed_size() {
        // 2 x 40: payload 2*20, scales 2*2 groups x 2 bytes
        let data = vec![0.25f32; 80];
        let m = Mat::quantize_q4_mat(2, 40, &data);
        assert_eq!(m.nbytes(), 40 + 8);
        assert_eq!(m.row_bytes(), 20 + 4);
        let m1 = Mat::quantize_q4_1_mat(2, 40, &data);
        assert_eq!(m1.nbytes(), 40 + 16);
        assert_eq!(m1.row_bytes(), 20 + 8);
        // odd cols: 2 x 33 -> 17 packed bytes + 2 groups per row
        let data = vec![0.25f32; 66];
        let m = Mat::quantize_q4_mat(2, 33, &data);
        assert_eq!(m.nbytes(), 34 + 8);
        assert_eq!(m.row_bytes(), 17 + 4);
    }

    #[test]
    fn dtype_bytes_for() {
        assert_eq!(DType::F32.bytes_for(&[2, 3]), Some(24));
        assert_eq!(DType::F16.bytes_for(&[5]), Some(10));
        assert_eq!(DType::Q4.bytes_for(&[4, 33]), Some(4 * 17));
        assert_eq!(DType::Q41.bytes_for(&[4, 32]), Some(4 * 16));
        // sub-byte dtypes are 2-D only
        assert_eq!(DType::Q4.bytes_for(&[8]), None);
        assert_eq!(DType::Q4.bytes_for(&[2, 2, 2]), None);
        // overflow must be caught, not wrapped
        assert_eq!(DType::F32.bytes_for(&[usize::MAX, usize::MAX]), None);
        assert_eq!(DType::Q4.bytes_for(&[usize::MAX, usize::MAX]), None);
    }

    #[test]
    fn q4_dtype_codes_round_trip() {
        assert!(matches!(DType::from_code(5), Ok(DType::Q4)));
        assert!(matches!(DType::from_code(6), Ok(DType::Q41)));
        assert!(DType::from_code(7).is_err());
    }
}
