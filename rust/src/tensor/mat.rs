//! Owned weight matrices in their storage precision.

use anyhow::{bail, Result};

use crate::util::f16::{f16_to_f32, f32_to_f16};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    F16,
    I8,
    U8,
    I32,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 => 2,
            DType::I8 | DType::U8 => 1,
        }
    }

    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::F16,
            2 => DType::I8,
            3 => DType::U8,
            4 => DType::I32,
            _ => bail!("unknown dtype code {c}"),
        })
    }
}

/// A 2-D weight matrix (rows x cols), row-major, owned.
///
/// `I8` carries the per-output scale vector (length = the *logical output
/// dimension*: `cols` for in-out layout, `rows` for row-per-output layout —
/// the consumer knows which).
#[derive(Clone, Debug)]
pub enum Mat {
    F32 { rows: usize, cols: usize, data: Vec<f32> },
    F16 { rows: usize, cols: usize, data: Vec<u16> },
    I8 { rows: usize, cols: usize, data: Vec<i8>, scale: Vec<f32> },
}

impl Mat {
    pub fn rows(&self) -> usize {
        match self {
            Mat::F32 { rows, .. } | Mat::F16 { rows, .. } | Mat::I8 { rows, .. } => *rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Mat::F32 { cols, .. } | Mat::F16 { cols, .. } | Mat::I8 { cols, .. } => *cols,
        }
    }

    /// Stored bytes (the memory-footprint accounting unit).
    pub fn nbytes(&self) -> u64 {
        match self {
            Mat::F32 { data, .. } => 4 * data.len() as u64,
            Mat::F16 { data, .. } => 2 * data.len() as u64,
            Mat::I8 { data, scale, .. } => data.len() as u64 + 4 * scale.len() as u64,
        }
    }

    /// Bytes of a single row in storage precision (sparse-load accounting).
    pub fn row_bytes(&self) -> u64 {
        let c = self.cols() as u64;
        match self {
            Mat::F32 { .. } => 4 * c,
            Mat::F16 { .. } => 2 * c,
            Mat::I8 { .. } => c + 4, // + its scale entry
        }
    }

    pub fn from_f32(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat::F32 { rows, cols, data }
    }

    pub fn f32_to_f16_mat(rows: usize, cols: usize, data: &[f32]) -> Self {
        Mat::F16 {
            rows,
            cols,
            data: data.iter().map(|&x| f32_to_f16(x)).collect(),
        }
    }

    /// Decode one row to f32 into `out` (row-per-output layout consumers).
    /// For `I8`, `scale_idx` selects the per-output scale (usually == row).
    pub fn decode_row(&self, row: usize, out: &mut [f32]) {
        let c = self.cols();
        debug_assert!(out.len() == c);
        match self {
            Mat::F32 { data, .. } => out.copy_from_slice(&data[row * c..(row + 1) * c]),
            Mat::F16 { data, .. } => {
                for (o, &h) in out.iter_mut().zip(&data[row * c..(row + 1) * c]) {
                    *o = f16_to_f32(h);
                }
            }
            Mat::I8 { data, scale, .. } => {
                if scale.len() == c {
                    // per-column scale ((in,out)-layout tensors, e.g. emb)
                    for ((o, &q), &s) in out
                        .iter_mut()
                        .zip(&data[row * c..(row + 1) * c])
                        .zip(scale.iter())
                    {
                        *o = q as f32 * s;
                    }
                } else {
                    // per-row scale (row-per-output tensors, e.g. head)
                    let s = scale[row];
                    for (o, &q) in out.iter_mut().zip(&data[row * c..(row + 1) * c]) {
                        *o = q as f32 * s;
                    }
                }
            }
        }
    }

    /// Full decode to f32 (used when uploading to the XLA backend).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        match self {
            Mat::F32 { data, .. } => data.clone(),
            Mat::F16 { data, .. } => data.iter().map(|&h| f16_to_f32(h)).collect(),
            Mat::I8 { rows, cols, data, scale } => {
                // scale is per-output; output dim may be rows or cols.  For
                // in-out layout scale.len() == cols; for row layout == rows.
                let mut out = vec![0f32; rows * cols];
                if scale.len() == *cols {
                    for r in 0..*rows {
                        for c in 0..*cols {
                            out[r * cols + c] = data[r * cols + c] as f32 * scale[c];
                        }
                    }
                } else {
                    debug_assert_eq!(scale.len(), *rows);
                    for r in 0..*rows {
                        let s = scale[r];
                        for c in 0..*cols {
                            out[r * cols + c] = data[r * cols + c] as f32 * s;
                        }
                    }
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_decode_f16() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let m = Mat::f32_to_f16_mat(2, 3, &data);
        let mut row = vec![0f32; 3];
        m.decode_row(1, &mut row);
        assert_eq!(row, vec![4.0, 5.0, 6.0]);
        assert_eq!(m.nbytes(), 12);
        assert_eq!(m.row_bytes(), 6);
    }

    #[test]
    fn i8_decode_row_per_row_scale() {
        // non-square, scale.len() == rows -> per-row semantics (head/wk_t)
        let m = Mat::I8 {
            rows: 2,
            cols: 3,
            data: vec![10, -20, 30, 40, 50, 60],
            scale: vec![0.1, 0.5],
        };
        let mut row = vec![0f32; 3];
        m.decode_row(0, &mut row);
        assert_eq!(row, vec![1.0, -2.0, 3.0]);
        m.decode_row(1, &mut row);
        assert_eq!(row, vec![20.0, 25.0, 30.0]);
    }

    #[test]
    fn i8_decode_row_per_column_scale() {
        // scale.len() == cols -> per-column semantics (emb, square mats)
        let m = Mat::I8 {
            rows: 2,
            cols: 2,
            data: vec![10, -20, 30, 40],
            scale: vec![0.1, 0.5],
        };
        let mut row = vec![0f32; 2];
        m.decode_row(0, &mut row);
        assert_eq!(row, vec![1.0, -10.0]);
        m.decode_row(1, &mut row);
        assert_eq!(row, vec![3.0, 20.0]);
    }

    #[test]
    fn to_f32_per_column_scale() {
        let m = Mat::I8 {
            rows: 1,
            cols: 2,
            data: vec![100, 50],
            scale: vec![0.01, 0.02],
        };
        assert_eq!(m.to_f32_vec(), vec![1.0, 1.0]);
    }
}
