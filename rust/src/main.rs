//! rwkv-lite — CLI entrypoint (leader process).
//!
//! Subcommands:
//!   generate   run one prompt through a model and print tokens
//!   serve      start the TCP serving front-end (coordinator + batcher)
//!   eval       run benchmark tasks through the engine
//!   exp <id>   regenerate a paper table/figure (DESIGN.md §5)
//!   info       model + artifact inventory

// Same unsafe-audit posture as the library crate (see `src/lib.rs`):
// every unsafe block must be justified and fully explicit.
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::undocumented_unsafe_blocks)]
#![warn(clippy::disallowed_types)]

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use rwkv_lite::sync::atomic::{AtomicBool, Ordering};
use rwkv_lite::sync::Arc;

use rwkv_lite::cli::{self, flag, opt, opt_def, Args};
use rwkv_lite::config::{Backend, EngineConfig, LoadStrategy, SimdMode};
use rwkv_lite::coordinator::{
    batcher::BatchPolicy, AdmissionPolicy, Coordinator, CoordinatorConfig,
};
use rwkv_lite::engine::sampler::Sampler;
use rwkv_lite::engine::session::Session;
use rwkv_lite::engine::state_cache::{CacheConfig, StateCache};
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::server::{ServeOptions, Server};
use rwkv_lite::text::Vocab;
use rwkv_lite::{evalsuite, exp};

const SPECS: &[cli::OptSpec] = &[
    opt_def("model", "model name under artifacts/models", "rwkv-ours-small"),
    opt_def("artifacts", "artifacts directory", "artifacts"),
    opt_def("strategy", "weight loading: full|layerwise", "full"),
    opt_def("backend", "compute backend: native|xla", "native"),
    flag("vanilla-runtime", "disable all techniques (dense runtime)"),
    flag("no-sparse", "disable sparse FFN"),
    flag("no-hh", "disable hierarchical head"),
    flag("no-emb-cache", "disable embedding cache"),
    opt("prompt", "prompt text (generate)"),
    opt("stop", "comma-separated stop words (generate)"),
    opt("stop-seq", "comma-separated multi-word stop sequences (generate)"),
    opt_def("n", "tokens to generate / measure", "64"),
    opt_def("temperature", "sampling temperature (0 = greedy)", "0.8"),
    opt_def("top-p", "nucleus mass", "0.95"),
    opt_def("prefill-chunk", "prompt tokens fused per round", "8"),
    opt_def("prefetch", "layerwise block prefetch (double-buffered): on|off", "on"),
    opt_def("threads", "intra-round compute threads (0 = all cores, 1 = serial)", "0"),
    opt_def("simd", "kernel backend: auto|scalar|neon|avx2 (all bit-identical)", "auto"),
    opt_def("limit", "max examples per eval task", "0"),
    opt_def("addr", "listen address (serve)", "127.0.0.1:7070"),
    opt_def("batch", "max dynamic batch size (serve)", "8"),
    opt_def("max-queue", "bounded admission: max queued requests (serve; 0 = unbounded)", "64"),
    opt_def("max-concurrency", "max in-flight sessions (serve; 0 = --batch)", "0"),
    opt_def("max-prompt-tokens", "reject prompts over this many tokens (serve; 0 = off)", "0"),
    opt_def("deadline-ms", "default per-request deadline (serve; 0 = none)", "0"),
    opt_def("drain-ms", "graceful-shutdown drain budget in ms (serve)", "5000"),
    opt_def("max-connections", "concurrent TCP connection cap (serve; 0 = unlimited)", "0"),
    opt_def("state-cache-mb", "prefix-state cache budget in MiB (serve; 0 = off)", "0"),
    opt("state-file", "persist the prefix-state cache across restarts (serve)"),
    opt_def("metrics", "serve GET /metrics + /stats on the serving port: on|off", "on"),
    opt("trace-out", "write the per-round trace ring as JSONL here at shutdown (serve)"),
    opt("task", "single task name (eval)"),
    opt("seed", "sampler seed"),
];

fn engine_config(a: &Args) -> Result<EngineConfig> {
    let model = a.get("model").context("--model required")?.to_string();
    let artifacts = PathBuf::from(a.get_or("artifacts", "artifacts"));
    let mut cfg = if a.flag("vanilla-runtime") {
        EngineConfig::vanilla(&model, artifacts)
    } else {
        EngineConfig::all_techniques(&model, artifacts)
    };
    // techniques only exist on checkpoints that carry their tensors; fall
    // back gracefully for vanilla checkpoints
    let manifest = rwkv_lite::io::Manifest::load(
        &cfg.artifacts.join("models").join(format!("{model}.json")),
    )?;
    if !manifest.has_predictors {
        cfg.sparse_ffn = false;
    }
    if !manifest.has_hier_head {
        cfg.hier_head = false;
    }
    if a.flag("no-sparse") {
        cfg.sparse_ffn = false;
    }
    if a.flag("no-hh") {
        cfg.hier_head = false;
    }
    if a.flag("no-emb-cache") {
        cfg.emb_cache = false;
    }
    cfg.strategy = LoadStrategy::parse(a.get_or("strategy", "full"))?;
    cfg.backend = Backend::parse(a.get_or("backend", "native"))?;
    cfg.prefill_chunk = a.usize_or("prefill-chunk", 8)?;
    cfg.prefetch = match a.get_or("prefetch", "on") {
        "on" => true,
        "off" => false,
        other => bail!("--prefetch takes on|off, got '{other}'"),
    };
    cfg.threads = a.usize_or("threads", 0)?;
    cfg.simd = SimdMode::parse(a.get_or("simd", "auto"))?;
    cfg.max_queue = a.usize_or("max-queue", 64)?;
    cfg.max_concurrency = a.usize_or("max-concurrency", 0)?;
    cfg.max_prompt_tokens = a.usize_or("max-prompt-tokens", 0)?;
    cfg.deadline_ms = a.u64_or("deadline-ms", 0)?;
    cfg.drain_ms = a.u64_or("drain-ms", 5000)?;
    cfg.state_cache_mb = a.usize_or("state-cache-mb", 0)?;
    cfg.state_file = a.get("state-file").map(PathBuf::from);
    cfg.metrics_endpoint = match a.get_or("metrics", "on") {
        "on" => true,
        "off" => false,
        other => bail!("--metrics takes on|off, got '{other}'"),
    };
    cfg.trace_out = a.get("trace-out").map(PathBuf::from);
    cfg.seed = a.u64_or("seed", 0)?;
    Ok(cfg)
}

fn vocab(a: &Args) -> Result<Vocab> {
    Vocab::load(
        &PathBuf::from(a.get_or("artifacts", "artifacts"))
            .join("data")
            .join("vocab.json"),
    )
}

fn cmd_generate(a: &Args) -> Result<()> {
    let cfg = engine_config(a)?;
    let v = vocab(a)?;
    let mut engine = RwkvEngine::load(cfg)?;
    let prompt_text = a.get("prompt").unwrap_or("the");
    let prompt = v.encode(prompt_text);
    let n = a.usize_or("n", 64)?;
    // one session driven round-by-round through the serving entry point
    let mut sess = Session::new(&engine, 0, &prompt);
    sess.max_tokens = n;
    sess.sampler = Sampler::new(
        a.f32_or("temperature", 0.8)?,
        a.f32_or("top-p", 0.95)?,
        a.u64_or("seed", 42)?,
    );
    if let Some(stops) = a.get("stop") {
        sess.stop_tokens =
            v.stop_token_ids(stops.split(',').map(|w| w.trim()).filter(|w| !w.is_empty()))?;
    }
    if let Some(seqs) = a.get("stop-seq") {
        for phrase in seqs.split(',').map(|p| p.trim()).filter(|p| !p.is_empty()) {
            sess.stop_seqs.push(v.stop_seq_ids(phrase)?);
        }
    }
    let t = rwkv_lite::util::Stopwatch::start();
    let out = engine.run_session(&mut sess)?;
    let secs = t.elapsed_secs();
    println!("{} {}", prompt_text, v.decode(&out));
    let (cur, peak) = engine.memory_report();
    eprintln!(
        "\n[{} tokens in {:.2}s = {:.1} tok/s | finish: {} | resident {} peak {}]",
        out.len(),
        secs,
        out.len() as f64 / secs,
        sess.finish_reason().map(|r| r.name()).unwrap_or("?"),
        rwkv_lite::util::fmt_bytes(cur),
        rwkv_lite::util::fmt_bytes(peak),
    );
    if let Some(c) = &engine.emb_cache {
        eprintln!(
            "[emb cache: {} entries, {:.0}% hit rate]",
            c.len(),
            100.0 * c.hit_rate()
        );
    }
    Ok(())
}

/// Process-wide shutdown latch flipped by the SIGINT/SIGTERM handler.
/// Signal handlers may only touch `static` atomics (async-signal-safe);
/// a watcher thread relays the latch into the serve/coordinator flags.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

extern "C" fn on_shutdown_signal(_sig: libc::c_int) {
    SHUTDOWN.store(true, Ordering::Release);
}

fn install_shutdown_handler() {
    let handler = on_shutdown_signal as extern "C" fn(libc::c_int);
    // SAFETY: `on_shutdown_signal` is async-signal-safe — it only stores
    // to a `static` atomic (no allocation, locking, or formatting), and
    // the handler pointer has the exact `extern "C" fn(c_int)` signature
    // `sighandler_t` expects for these two signals.
    unsafe {
        libc::signal(libc::SIGINT, handler as libc::sighandler_t);
        libc::signal(libc::SIGTERM, handler as libc::sighandler_t);
    }
}

fn cmd_serve(a: &Args) -> Result<()> {
    let cfg = engine_config(a)?;
    let v = vocab(a)?;
    let policy = BatchPolicy { max_batch: a.usize_or("batch", 8)?, window_ms: 2 };
    // bounded admission / deadlines / drain budget all ride on the engine
    // config (--max-queue, --max-concurrency, --max-prompt-tokens,
    // --deadline-ms, --drain-ms)
    let admission = AdmissionPolicy::from_config(&cfg);
    let max_connections = a.usize_or("max-connections", 0)?;
    // ONE compute pool for the process, its handle threaded through the
    // coordinator's engine factory: every scheduling round fans out over
    // these workers (--threads; 0 = all cores)
    let pool = rwkv_lite::pool::for_threads(cfg.threads);
    // one prefix-state cache shared across all requests (--state-cache-mb;
    // --state-file persists its snapshots across restarts)
    let cache = (cfg.state_cache_mb > 0)
        .then(|| StateCache::new(CacheConfig::with_mb(cfg.state_cache_mb)));
    let state_file = cfg.state_file.clone();
    let trace_out = cfg.trace_out.clone();
    let metrics_endpoint = cfg.metrics_endpoint;
    let coordinator = Coordinator::spawn_cfg(
        move || RwkvEngine::load_with_pool(cfg, pool),
        CoordinatorConfig {
            policy,
            admission,
            cache,
            state_file,
            trace_out,
            ..CoordinatorConfig::default()
        },
    );
    let server = Arc::new(Server::new(coordinator, v));
    // graceful shutdown: signal -> static latch -> watcher thread flips
    // the accept-loop flag AND starts the coordinator drain, so in-flight
    // requests finish (or hit the drain budget) while the listener stops
    // taking new connections
    install_shutdown_handler();
    let stop_accepting = Arc::new(AtomicBool::new(false));
    {
        let flag = Arc::clone(&stop_accepting);
        let coord = Arc::clone(&server.coordinator);
        std::thread::spawn(move || loop {
            if SHUTDOWN.load(Ordering::Acquire) {
                eprintln!("[serve] shutdown signal: draining");
                coord.begin_shutdown();
                flag.store(true, Ordering::Release);
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
        });
    }
    let opts = ServeOptions {
        max_total_conns: None,
        max_connections,
        shutdown: Some(Arc::clone(&stop_accepting)),
        metrics_endpoint,
    };
    Arc::clone(&server).serve(a.get_or("addr", "127.0.0.1:7070"), opts)?;
    // serve returned with every connection thread joined; ensure the
    // drain runs even on non-signal exits, then release the last server
    // handle so the coordinator thread finishes (persisting its
    // statefile) before the process exits
    server.coordinator.begin_shutdown();
    drop(server);
    Ok(())
}

fn cmd_eval(a: &Args) -> Result<()> {
    let cfg = engine_config(a)?;
    let mut engine = RwkvEngine::load(cfg)?;
    let tasks = evalsuite::load_tasks(
        &PathBuf::from(a.get_or("artifacts", "artifacts"))
            .join("data")
            .join("tasks.json"),
    )?;
    let limit = a.usize_or("limit", 0)?;
    println!("{:<16} {:>8} {:>8} {:>6}", "task", "acc", "ppl", "n");
    for (name, task) in &tasks {
        if let Some(only) = a.get("task") {
            if only != name {
                continue;
            }
        }
        let r = evalsuite::eval_task(&mut engine, task, limit)?;
        println!("{:<16} {:>8.3} {:>8.2} {:>6}", name, r.acc, r.ppl, r.n);
    }
    Ok(())
}

fn cmd_info(a: &Args) -> Result<()> {
    let dir = PathBuf::from(a.get_or("artifacts", "artifacts")).join("models");
    println!(
        "{:<28} {:>9} {:>6} {:>7} {:>6} {:>6} {:>5}",
        "model", "MiB", "dim", "layers", "pred", "hh", "prec"
    );
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .with_context(|| format!("{} (run `make artifacts`)", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|e| e == "json").unwrap_or(false))
        .collect();
    entries.sort();
    for p in entries {
        if let Ok(m) = rwkv_lite::io::Manifest::load(&p) {
            let rkv = m.rkv_path();
            let bytes = std::fs::metadata(&rkv).map(|md| md.len()).unwrap_or(0);
            println!(
                "{:<28} {:>9.2} {:>6} {:>7} {:>6} {:>6} {:>5}",
                m.name,
                bytes as f64 / (1 << 20) as f64,
                m.dim,
                m.layers,
                m.has_predictors,
                m.has_hier_head,
                m.precision
            );
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let a = cli::parse(&argv, SPECS)?;
    let cmd = a.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "generate" => cmd_generate(&a),
        "serve" => cmd_serve(&a),
        "eval" => cmd_eval(&a),
        "info" => cmd_info(&a),
        "exp" => {
            let id = a
                .positional
                .get(1)
                .context("usage: rwkv-lite exp <table1|fig3|...|all>")?;
            exp::run(id, &a)
        }
        other => {
            println!(
                "rwkv-lite — deeply compressed RWKV inference (paper reproduction)\n\n\
                 usage: rwkv-lite <generate|serve|eval|exp|info> [options]\n\n{}",
                cli::usage(SPECS)
            );
            if other != "help" {
                bail!("unknown command '{other}'");
            }
            Ok(())
        }
    }
}
