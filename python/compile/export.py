"""`.rkv` checkpoint writer — the python -> rust interchange (S11).

Binary layout (little-endian; mirrored by rust/src/io/rkv.rs):

    magic   b"RKV1"
    u32     version = 1
    u32     n_tensors
    u64     data_offset           # absolute file offset of the data section
    n_tensors x index entry:
        u16  name_len, name (utf-8)
        u8   dtype                # 0=f32 1=f16 2=i8 3=u8 4=i32
        u8   ndim
        u32  dims[ndim]
        u64  offset               # relative to data_offset
        u64  nbytes
    data section (64-byte aligned; each tensor 64-byte aligned)

Tensor naming convention (consumed by rust/src/engine/weights.rs):
    emb (V,D)  ln0.scale/bias  ln_out.scale/bias
    head (V,D)            # stored TRANSPOSED (row per vocab token) so the
                          # hierarchical head (§3.3) loads contiguous rows
    b{i}.ln1.scale/bias   b{i}.ln2.scale/bias
    b{i}.att.mu_r|mu_k|mu_v|mu_g          (D,)
    b{i}.att.decay (H,S)   # precomputed exp(-exp(decay_log))
    b{i}.att.first (H,S)
    b{i}.att.wr.w | b{i}.att.wr.l/.r[/.d] (projection representations)
    ... same for wk, wv, wg;  b{i}.att.wo.w always dense
    b{i}.att.lnx.scale/bias
    b{i}.ffn.mu_k|mu_r   b{i}.ffn.wr.*
    b{i}.ffn.wk_t (F,D)   # wk stored TRANSPOSED: one row per FFN neuron so
                          # the sparse loader (§3.2) reads contiguous rows
    b{i}.ffn.wv (F,D)     # already row-per-neuron
    b{i}.pred.l1 (D,N)  b{i}.pred.l2 (N,F)           # MLP predictor
    b{i}.pred.sign (ceil(D/8),F) u8  b{i}.pred.scale (F,)   # 1-bit shadow
    hh.h1 (D,C)   hh.assign (V,) i32                  # hierarchical head

INT8 export: matrix tensors become dtype i8 with a sibling  <name>.scale
(out_features,) f32 per-column scale — exactly what the rust fused
dequant kernels consume.

A JSON manifest `<name>.json` sits next to each `.rkv` (config, runtime
thresholds, component->HLO-parameter-order mapping).
"""

from __future__ import annotations

import json
import os
import struct
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .common import ModelConfig
from .compress import quant

DTYPES = {"f32": 0, "f16": 1, "i8": 2, "u8": 3, "i32": 4, "q4": 5, "q4_1": 6}
_NP_OF = {0: np.float32, 1: np.float16, 2: np.int8, 3: np.uint8, 4: np.int32}

ALIGN = 64


def _dtype_code(a: np.ndarray) -> int:
    for code, npdt in _NP_OF.items():
        if a.dtype == npdt:
            return code
    raise TypeError(f"unsupported dtype {a.dtype}")


class PackedTensor:
    """A sub-byte tensor staged for `write_rkv`: the dtype code cannot be
    inferred from a numpy dtype, and the LOGICAL shape (rows, cols) does
    not match the packed payload's byte count, so both are explicit.

    For q4/q4_1 the payload is the (rows, ceil(cols/2)) nibble-packed u8
    array from `compress.quant.group_q4`/`group_q4_1`; the per-group f16
    siblings are staged as ordinary float16 arrays alongside.
    """

    def __init__(self, code: int, shape: Tuple[int, ...], data: np.ndarray):
        self.code = int(code)
        self.shape = tuple(int(d) for d in shape)
        self.data = np.ascontiguousarray(data, np.uint8)

    @property
    def nbytes(self) -> int:
        return self.data.nbytes


def _staged(v) -> Tuple[np.ndarray, int, Tuple[int, ...]]:
    """Normalize a tensor-dict value to (payload array, dtype code, shape)."""
    if isinstance(v, PackedTensor):
        return v.data, v.code, v.shape
    a = np.ascontiguousarray(v)
    return a, _dtype_code(a), a.shape


def write_rkv(path: str, tensors: Dict[str, Any]) -> int:
    """Write tensors (ndarrays or PackedTensors); returns bytes written."""
    names = list(tensors.keys())
    index: List[Tuple[str, np.ndarray, int, Tuple[int, ...], int]] = []
    off = 0
    for n in names:
        a, code, shape = _staged(tensors[n])
        off = (off + ALIGN - 1) // ALIGN * ALIGN
        index.append((n, a, code, shape, off))
        off += a.nbytes

    header = bytearray()
    header += b"RKV1"
    header += struct.pack("<II", 1, len(names))
    header_fixed_end = len(header) + 8  # u64 data_offset comes next
    body = bytearray()
    for n, a, code, shape, toff in index:
        nb = n.encode()
        body += struct.pack("<H", len(nb)) + nb
        body += struct.pack("<BB", code, len(shape))
        body += struct.pack(f"<{len(shape)}I", *shape)
        body += struct.pack("<QQ", toff, a.nbytes)
    data_offset = (header_fixed_end + len(body) + ALIGN - 1) // ALIGN * ALIGN
    header += struct.pack("<Q", data_offset)

    with open(path, "wb") as f:
        f.write(header)
        f.write(body)
        f.write(b"\0" * (data_offset - header_fixed_end - len(body)))
        pos = 0
        for n, a, code, shape, toff in index:
            if toff > pos:
                f.write(b"\0" * (toff - pos))
                pos = toff
            f.write(a.tobytes())
            pos += a.nbytes
        total = data_offset + pos
    return total


def read_rkv(path: str) -> Dict[str, Any]:
    """Reader (used by round-trip tests; rust has its own).  Sub-byte
    tensors come back as `PackedTensor` (payload bytes + logical shape)."""
    with open(path, "rb") as f:
        raw = f.read()
    assert raw[:4] == b"RKV1"
    version, n = struct.unpack_from("<II", raw, 4)
    assert version == 1
    (data_offset,) = struct.unpack_from("<Q", raw, 12)
    pos = 20
    out: Dict[str, Any] = {}
    for _ in range(n):
        (nl,) = struct.unpack_from("<H", raw, pos)
        pos += 2
        name = raw[pos : pos + nl].decode()
        pos += nl
        dt, nd = struct.unpack_from("<BB", raw, pos)
        pos += 2
        dims = struct.unpack_from(f"<{nd}I", raw, pos)
        pos += 4 * nd
        off, nbytes = struct.unpack_from("<QQ", raw, pos)
        pos += 16
        if dt in (DTYPES["q4"], DTYPES["q4_1"]):
            rows, cols = dims
            payload = np.frombuffer(raw, np.uint8, count=nbytes, offset=data_offset + off)
            out[name] = PackedTensor(dt, dims, payload.reshape(rows, (cols + 1) // 2))
        else:
            a = np.frombuffer(raw, dtype=_NP_OF[dt], count=nbytes // np.dtype(_NP_OF[dt]).itemsize, offset=data_offset + off)
            out[name] = a.reshape(dims)
    return out


# ---------------------------------------------------------------------------
# Model export
# ---------------------------------------------------------------------------

# Matrices >= this many elements are stored f16 (fp16 export) / int8
# (quantized export); small vectors stay f32.
_MATRIX_MIN = 1 << 12


def _emit(tensors: Dict[str, Any], name: str, a: np.ndarray, precision: str,
          transpose: bool = False):
    """Store a tensor; if `transpose`, quantize per-output-column first (the
    semantics of the original x@W orientation) then store W^T row-major.

    `q4`/`q4_1` group-quantize along the STORED row axis (32-element
    groups) and stage the packed nibbles plus f16 `.scale` (and `.min`)
    siblings — exactly the layout rust `tensor::q4` consumes."""
    a = np.asarray(a)
    if a.ndim == 2 and a.size >= _MATRIX_MIN and precision in ("f16", "int8", "q4", "q4_1"):
        if precision == "f16":
            tensors[name] = (a.T if transpose else a).astype(np.float16)
        elif precision in ("q4", "q4_1"):
            w = np.ascontiguousarray(a.T if transpose else a, np.float32)
            if precision == "q4":
                packed, scale = quant.group_q4(w)
            else:
                packed, scale, mn = quant.group_q4_1(w)
                tensors[name + ".min"] = mn
            tensors[name] = PackedTensor(DTYPES[precision], w.shape, packed)
            tensors[name + ".scale"] = scale
        else:
            q, scale = quant.int_quant(a.astype(np.float32), 8)
            tensors[name] = np.ascontiguousarray(q.T) if transpose else q
            tensors[name + ".scale"] = scale
    else:
        tensors[name] = (a.T if transpose else a).astype(np.float32)


def _emit_proj(tensors, prefix: str, p: Dict[str, np.ndarray], precision: str):
    for key in ("w", "l", "r", "d"):
        if key in p:
            # hybrid recipe (RWKVQuant): only the large dense `.w` takes
            # the group-quantized format; low-rank factors are small and
            # outlier-dense, so they stay f16 under a q4 export
            kp = precision
            if precision in ("q4", "q4_1") and key != "w":
                kp = "f16"
            _emit(tensors, f"{prefix}.{key}", p[key], kp)


def model_tensors(
    params: Dict[str, Any],
    cfg: ModelConfig,
    precision: str = "f16",
    predictors: Optional[List[Dict[str, np.ndarray]]] = None,
    shadows: Optional[List[Dict[str, np.ndarray]]] = None,
    hier_head: Optional[Dict[str, np.ndarray]] = None,
    shadows4: Optional[List[Dict[str, np.ndarray]]] = None,
) -> Dict[str, np.ndarray]:
    t: Dict[str, Any] = {}
    # hybrid selection under a q4 export: embeddings are row-streamed and
    # outlier-heavy, so they stay f16; ffn.wv takes the offset-carrying
    # q4_1 variant; everything else large and dense goes q4
    qmode = precision in ("q4", "q4_1")
    emb_prec = "f16" if qmode else precision
    wv_prec = "q4_1" if qmode else precision
    _emit(t, "emb", params["emb"], emb_prec)
    # head stored transposed (V, D): row per vocab token (see module doc).
    _emit(t, "head", params["head"], precision, transpose=True)
    for ln in ("ln0", "ln_out"):
        t[f"{ln}.scale"] = np.asarray(params[ln]["scale"], np.float32)
        t[f"{ln}.bias"] = np.asarray(params[ln]["bias"], np.float32)
    for i, b in enumerate(params["blocks"]):
        p = f"b{i}"
        for ln in ("ln1", "ln2"):
            t[f"{p}.{ln}.scale"] = np.asarray(b[ln]["scale"], np.float32)
            t[f"{p}.{ln}.bias"] = np.asarray(b[ln]["bias"], np.float32)
        att = b["att"]
        for mu in ("mu_r", "mu_k", "mu_v", "mu_g"):
            t[f"{p}.att.{mu}"] = np.asarray(att[mu], np.float32)
        t[f"{p}.att.decay"] = np.exp(-np.exp(np.asarray(att["decay_log"], np.float32)))
        t[f"{p}.att.first"] = np.asarray(att["first"], np.float32)
        for w in ("wr", "wk", "wv", "wg", "wo"):
            _emit_proj(t, f"{p}.att.{w}", att[w], precision)
        t[f"{p}.att.lnx.scale"] = np.asarray(att["ln_x"]["scale"], np.float32)
        t[f"{p}.att.lnx.bias"] = np.asarray(att["ln_x"]["bias"], np.float32)
        ffn = b["ffn"]
        for mu in ("mu_k", "mu_r"):
            t[f"{p}.ffn.{mu}"] = np.asarray(ffn[mu], np.float32)
        _emit_proj(t, f"{p}.ffn.wr", ffn["wr"], precision)
        # wk stored transposed (F, D): row per FFN neuron (see module doc).
        _emit(t, f"{p}.ffn.wk_t", ffn["wk"], precision, transpose=True)
        _emit(t, f"{p}.ffn.wv", ffn["wv"], wv_prec)
        if predictors is not None:
            # predictors are auxiliary nets: always INT8 regardless of the
            # model precision (their job is a binary decision; quantization
            # noise is absorbed by the ensemble's union with the 1-bit
            # shadow)
            for leaf in ("l1", "l2"):
                q, scale = quant.int_quant(np.asarray(predictors[i][leaf], np.float32), 8)
                t[f"{p}.pred.{leaf}"] = q
                t[f"{p}.pred.{leaf}.scale"] = scale
        if shadows is not None:
            t[f"{p}.pred.sign"] = np.asarray(shadows[i]["wq_packed"], np.uint8)
            t[f"{p}.pred.scale"] = np.asarray(shadows[i]["wq_scale"], np.float32)
        if shadows4 is not None:
            # 4-bit shadow (fig9's n-bit predictor study)
            t[f"{p}.pred.q4"] = np.asarray(shadows4[i]["wq4_packed"], np.uint8)
            t[f"{p}.pred.q4.scale"] = np.asarray(shadows4[i]["wq4_scale"], np.float32)
    if hier_head is not None:
        # h1 stored transposed (C, D): row per cluster (rust matvec_rows)
        _emit(t, "hh.h1", hier_head["h1"], precision, transpose=True)
        t["hh.assign"] = np.asarray(hier_head["assign"], np.int32)
    return t


def transformer_tensors(params: Dict[str, Any], cfg: ModelConfig, precision: str = "f16") -> Dict[str, np.ndarray]:
    """Baseline GPT tensors: emb/pos/head/ln_out + per-block attn & MLP."""
    t: Dict[str, np.ndarray] = {}
    _emit(t, "emb", params["emb"], precision)
    _emit(t, "pos", params["pos"], precision)
    # head transposed (V, D), matching the RWKV layout (row per token)
    _emit(t, "head", params["head"], precision, transpose=True)
    t["ln_out.scale"] = np.asarray(params["ln_out"]["scale"], np.float32)
    t["ln_out.bias"] = np.asarray(params["ln_out"]["bias"], np.float32)
    for i, b in enumerate(params["blocks"]):
        p = f"b{i}"
        for ln in ("ln1", "ln2"):
            t[f"{p}.{ln}.scale"] = np.asarray(b[ln]["scale"], np.float32)
            t[f"{p}.{ln}.bias"] = np.asarray(b[ln]["bias"], np.float32)
        for w in ("wq", "wk", "wv", "wo"):
            _emit(t, f"{p}.att.{w}", b[w], precision)
        _emit(t, f"{p}.mlp.up", b["mlp_up"], precision)
        _emit(t, f"{p}.mlp.down", b["mlp_down"], precision)
    return t


def export_transformer(
    out_dir: str, name: str, params: Dict[str, Any], cfg: ModelConfig, precision: str = "f16",
    extra_manifest: Optional[Dict[str, Any]] = None,
) -> str:
    tensors = transformer_tensors(params, cfg, precision)
    path = os.path.join(out_dir, f"{name}.rkv")
    nbytes = write_rkv(path, tensors)
    manifest = {
        "name": name,
        "precision": precision,
        "config": cfg.to_json(),
        "heads": cfg.heads,
        "mlp_mult": 4,
        "max_seq": 512,
        "n_bytes": nbytes,
        "has_predictors": False,
        "has_hier_head": False,
        "runtime": {},
    }
    if extra_manifest:
        manifest.update(extra_manifest)
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return path


def export_model(
    out_dir: str,
    name: str,
    params: Dict[str, Any],
    cfg: ModelConfig,
    precision: str = "f16",
    predictors=None,
    shadows=None,
    hier_head=None,
    shadows4=None,
    extra_manifest: Optional[Dict[str, Any]] = None,
) -> str:
    """Write `<out_dir>/<name>.rkv` + `<name>.json`; returns the rkv path."""
    tensors = model_tensors(params, cfg, precision, predictors, shadows, hier_head, shadows4)
    path = os.path.join(out_dir, f"{name}.rkv")
    nbytes = write_rkv(path, tensors)
    manifest = {
        "name": name,
        "precision": precision,
        "config": cfg.to_json(),
        "ffn_dim": cfg.ffn_dim,
        "heads": cfg.heads,
        "n_bytes": nbytes,
        "has_predictors": predictors is not None,
        "has_hier_head": hier_head is not None,
        "runtime": {
            "t_mlp": 0.7,
            "t_quant": 0.8,
            "hh_p_min": 0.95,
            "hh_k_min": 3,
            "hh_k_max": 16,
            "emb_cache_capacity": 64,
        },
    }
    if extra_manifest:
        manifest.update(extra_manifest)
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return path
