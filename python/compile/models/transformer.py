"""Decoder-only transformer baseline (OPT / GPT-Neo / TinyLlama stand-in).

Substrate S3: Figures 5 and 10 compare RWKV(-Lite) against transformer LLMs
of matched dims.  We implement a standard pre-LN GPT: learned positional
embeddings, multi-head causal attention (same head_size=16 as the RWKV
variants), GELU MLP with 4D hidden.  Trained on the same synthetic corpus
by `python/compile/train.py`.

Unlike RWKV, inference requires a KV cache that grows O(T) — the memory
comparison in Fig. 5 deliberately *excludes* it (favoring transformers),
and so do we; the rust engine still implements the cache because the
baseline has to actually run (rust/src/engine/transformer.rs).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..common import ModelConfig, orthogonal_init, rng

Params = Dict[str, Any]

MAX_SEQ = 512  # learned positional table size
MLP_MULT = 4


def init(cfg: ModelConfig, seed: int = 0) -> Params:
    g = rng(seed)
    d, v = cfg.dim, cfg.vocab
    params: Params = {
        "emb": (0.02 * g.standard_normal((v, d))).astype(np.float32),
        "pos": (0.02 * g.standard_normal((MAX_SEQ, d))).astype(np.float32),
        "ln_out": {"scale": np.ones(d, np.float32), "bias": np.zeros(d, np.float32)},
        "head": orthogonal_init(g, (d, v), 0.5),
        "blocks": [],
    }
    for _ in range(cfg.layers):
        params["blocks"].append(
            {
                "ln1": {"scale": np.ones(d, np.float32), "bias": np.zeros(d, np.float32)},
                "ln2": {"scale": np.ones(d, np.float32), "bias": np.zeros(d, np.float32)},
                "wq": orthogonal_init(g, (d, d), 1.0),
                "wk": orthogonal_init(g, (d, d), 1.0),
                "wv": orthogonal_init(g, (d, d), 1.0),
                "wo": np.zeros((d, d), np.float32),
                "mlp_up": orthogonal_init(g, (d, MLP_MULT * d), 1.0),
                "mlp_down": np.zeros((MLP_MULT * d, d), np.float32),
            }
        )
    return params


def _ln(x, p):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * p["scale"] + p["bias"]


def _attn(x, blk, cfg: ModelConfig):
    b, t, d = x.shape
    h, s = cfg.heads, cfg.head_size
    q = (x @ blk["wq"]).reshape(b, t, h, s)
    k = (x @ blk["wk"]).reshape(b, t, h, s)
    v = (x @ blk["wv"]).reshape(b, t, h, s)
    att = jnp.einsum("bthd,bshd->bhts", q, k) / np.sqrt(s)
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", att, v).reshape(b, t, d)
    return out @ blk["wo"]


def forward(params: Params, cfg: ModelConfig, tokens) -> jnp.ndarray:
    """(B, T) -> (B, T, V) logits."""
    b, t = tokens.shape
    x = params["emb"][tokens] + params["pos"][:t]
    for blk in params["blocks"]:
        x = x + _attn(_ln(x, blk["ln1"]), blk, cfg)
        hdn = jax.nn.gelu(_ln(x, blk["ln2"]) @ blk["mlp_up"])
        x = x + hdn @ blk["mlp_down"]
    x = _ln(x, params["ln_out"])
    return x @ params["head"]


def param_groups(params: Params, cfg: ModelConfig) -> Dict[str, int]:
    def size(x):
        return int(np.prod(np.asarray(x).shape))

    sq = nonsq = other = 0
    for b in params["blocks"]:
        sq += sum(size(b[k]) for k in ("wq", "wk", "wv", "wo"))
        nonsq += size(b["mlp_up"]) + size(b["mlp_down"])
        other += sum(size(b[ln][f]) for ln in ("ln1", "ln2") for f in ("scale", "bias"))
    other += size(params["pos"])
    other += sum(size(params["ln_out"][f]) for f in ("scale", "bias"))
    return {
        "square": sq,
        "non_square": nonsq,
        "head": size(params["head"]),
        "emb": size(params["emb"]),
        "other": other,
    }
