from . import rwkv, transformer  # noqa: F401
