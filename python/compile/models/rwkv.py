"""RWKV v5 ("Eagle") in JAX — vanilla and RWKV-Lite variants.

Implemented from scratch (substrate S1/S2 in DESIGN.md): token-shift lerps,
multi-head WKV recurrence with per-channel decay/bonus, per-head GroupNorm,
squared-ReLU channel-mix.  The RWKV-Lite variants replace the square
projections W_{r,k,v,g} (time-mix) and W_r (channel-mix) — but, per the
paper, *not* W_o — with low-rank factors (simple SVD, Eq. 1) or the
enhanced construct (Eq. 2).

Two forward entry points:
  * `forward(params, cfg, tokens)`   — (B, T) -> (B, T, V) logits, used for
    training/eval; pure-jnp math (fast on CPU).
  * `step(params, cfg, x, state)`    — single-token decode step used by the
    AOT lowering; routes the WKV recurrence / FFN / low-rank projections
    through the L1 kernels (impl="pallas") so they ship in the HLO.

Parameter pytree layout (all float32 numpy/jnp arrays):
  emb        (V, D)
  ln0 / ln_out: {scale, bias} (D,)
  head       (D, V)
  blocks: list of L dicts:
    ln1, ln2: {scale, bias}
    att: mu_r/k/v/g (D,), decay_log (H,S), first (H,S),
         wr/wk/wv/wg: projection (see `_proj`), wo (D, D) always dense,
         ln_x: {scale, bias} (D,)  per-head group norm
    ffn: mu_k, mu_r (D,), wr: projection, wk (D, F), wv (F, D)
Projections are dicts: {"w"} dense | {"l","r"} simple SVD | {"l","r","d"}
enhanced SVD.  The pytree *structure* encodes the variant, so jit caches
one executable per variant.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import ModelConfig, orthogonal_init, rng
from .. import kernels

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _ln_init(d: int) -> Params:
    return {"scale": np.ones(d, np.float32), "bias": np.zeros(d, np.float32)}


def _proj_init(g: np.random.Generator, cfg: ModelConfig, gain: float, zero: bool = False) -> Params:
    d = cfg.dim
    if zero:
        return {"w": np.zeros((d, d), np.float32)}
    if cfg.svd_rank_div == 0:
        return {"w": orthogonal_init(g, (d, d), gain)}
    r = cfg.svd_rank
    if cfg.enhanced_svd:
        return {
            "l": orthogonal_init(g, (d, r), gain),
            "r": orthogonal_init(g, (r, d), gain),
            "d": (0.1 * g.standard_normal(d)).astype(np.float32),
        }
    return {
        "l": orthogonal_init(g, (d, r), gain),
        "r": orthogonal_init(g, (r, d), gain),
    }


def init(cfg: ModelConfig, seed: int = 0) -> Params:
    """Random init following the official RWKV trainer's recipes (scaled)."""
    g = rng(seed)
    d, v, h, s, f, n_layers = cfg.dim, cfg.vocab, cfg.heads, cfg.head_size, cfg.ffn_dim, cfg.layers
    params: Params = {
        "emb": (1e-4 * g.standard_normal((v, d))).astype(np.float32),
        "ln0": _ln_init(d),
        "ln_out": _ln_init(d),
        "head": orthogonal_init(g, (d, v), 0.5),
        "blocks": [],
    }
    ddd = (np.arange(d, dtype=np.float32) / d)
    for layer in range(n_layers):
        r01 = layer / max(1, n_layers - 1)
        r1a0 = 1.0 - layer / n_layers
        mu = lambda p: np.power(ddd, p).astype(np.float32)  # noqa: E731
        decay = -6.0 + 5.0 * np.power(
            np.arange(h * s, dtype=np.float32) / max(1, h * s - 1), 0.7 + 1.3 * r01
        )
        first = 0.5 * (np.arange(h * s) % 3 - 1).astype(np.float32) + np.log(0.3)
        block = {
            "ln1": _ln_init(d),
            "ln2": _ln_init(d),
            "att": {
                "mu_r": 0.5 * mu(0.5 * r1a0),
                "mu_k": mu(r1a0),
                "mu_v": mu(r1a0) + 0.3 * r01,
                "mu_g": 0.5 * mu(0.5 * r1a0),
                "decay_log": decay.reshape(h, s).astype(np.float32),
                "first": first.reshape(h, s).astype(np.float32),
                "wr": _proj_init(g, cfg, 1.0),
                "wk": _proj_init(g, cfg, 0.8),
                "wv": _proj_init(g, cfg, 1.0),
                "wg": _proj_init(g, cfg, 0.8),
                "wo": {"w": np.zeros((d, d), np.float32)},
                "ln_x": _ln_init(d),
            },
            "ffn": {
                "mu_k": mu(r1a0),
                "mu_r": mu(r1a0),
                "wr": _proj_init(g, cfg, 1.0),
                "wk": orthogonal_init(g, (d, f), 1.0),
                "wv": np.zeros((f, d), np.float32),
            },
        }
        params["blocks"].append(block)
    return params


def init_state(cfg: ModelConfig, batch: int | None = None) -> Params:
    """Zero recurrent state. Arrays are (L, ...) stacked for easy interchange."""
    h, s, d, n_layers = cfg.heads, cfg.head_size, cfg.dim, cfg.layers
    shp = (lambda *dims: (batch, *dims) if batch else dims)
    return {
        "att_x": jnp.zeros(shp(n_layers, d), jnp.float32),
        "wkv": jnp.zeros(shp(n_layers, h, s, s), jnp.float32),
        "ffn_x": jnp.zeros(shp(n_layers, d), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Shared math
# ---------------------------------------------------------------------------


def _ln(x, p):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * p["scale"] + p["bias"]


def _group_norm_heads(x, p, heads: int):
    """Per-head GroupNorm (the official ln_x): x (..., D) grouped into H."""
    shp = x.shape
    xh = x.reshape(*shp[:-1], heads, shp[-1] // heads)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) / jnp.sqrt(var + 64e-5)  # official uses eps*head_size scale
    return xh.reshape(shp) * p["scale"] + p["bias"]


def _proj(x, p: Params, kns) -> jnp.ndarray:
    """Apply a projection in whichever representation it is stored."""
    if "w" in p:
        return x @ p["w"]
    if "d" in p:
        return kns.enhanced_lowrank_proj(x, p["l"], p["r"], p["d"])
    return kns.lowrank_proj(x, p["l"], p["r"])


def _lerp(x, x_prev, mu):
    """RWKV token-shift lerp: mu*x + (1-mu)*x_prev."""
    return x * mu + x_prev * (1.0 - mu)


# ---------------------------------------------------------------------------
# Training/eval forward over full sequences (pure jnp; batched)
# ---------------------------------------------------------------------------


def _shift(x):
    """(B, T, D) -> previous-token tensor with zeros at t=0."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


def _time_mix_seq(x, att: Params, cfg: ModelConfig):
    b, t, d = x.shape
    h, s = cfg.heads, cfg.head_size
    kns = kernels.get("jnp")
    sx = _shift(x)
    r = _proj(_lerp(x, sx, att["mu_r"]), att["wr"], kns)
    k = _proj(_lerp(x, sx, att["mu_k"]), att["wk"], kns)
    v = _proj(_lerp(x, sx, att["mu_v"]), att["wv"], kns)
    g = _proj(_lerp(x, sx, att["mu_g"]), att["wg"], kns)
    g = g * jax.nn.sigmoid(g)  # SiLU gate
    w = jnp.exp(-jnp.exp(att["decay_log"]))
    u = att["first"]
    rh = r.reshape(b, t, h, s)
    kh = k.reshape(b, t, h, s)
    vh = v.reshape(b, t, h, s)
    state0 = jnp.zeros((b, h, s, s), jnp.float32)
    out, _ = jax.vmap(lambda rr, kk, vv, st: kns.wkv5_seq(rr, kk, vv, w, u, st))(
        rh, kh, vh, state0
    )
    out = out.reshape(b, t, d)
    out = _group_norm_heads(out, att["ln_x"], h) * g
    return _proj(out, att["wo"], kns)


def _chan_mix_seq(x, ffn: Params, cfg: ModelConfig):
    kns = kernels.get("jnp")
    sx = _shift(x)
    xk = _lerp(x, sx, ffn["mu_k"])
    xr = _lerp(x, sx, ffn["mu_r"])
    r = jax.nn.sigmoid(_proj(xr, ffn["wr"], kns))
    return r * kns.sqrelu_ffn(xk, ffn["wk"], ffn["wv"])


def forward(params: Params, cfg: ModelConfig, tokens) -> jnp.ndarray:
    """(B, T) int32 -> (B, T, V) logits."""
    x = params["emb"][tokens]
    x = _ln(x, params["ln0"])
    for block in params["blocks"]:
        x = x + _time_mix_seq(_ln(x, block["ln1"]), block["att"], cfg)
        x = x + _chan_mix_seq(_ln(x, block["ln2"]), block["ffn"], cfg)
    x = _ln(x, params["ln_out"])
    return x @ params["head"]


# ---------------------------------------------------------------------------
# Single-token decode step (the AOT surface; L1 kernels)
# ---------------------------------------------------------------------------


def _time_mix_step(x, att_x_prev, wkv_state, att: Params, cfg: ModelConfig, impl: str):
    h, s = cfg.heads, cfg.head_size
    kns = kernels.get(impl)
    r = _proj(_lerp(x, att_x_prev, att["mu_r"]), att["wr"], kns)
    k = _proj(_lerp(x, att_x_prev, att["mu_k"]), att["wk"], kns)
    v = _proj(_lerp(x, att_x_prev, att["mu_v"]), att["wv"], kns)
    g = _proj(_lerp(x, att_x_prev, att["mu_g"]), att["wg"], kns)
    g = g * jax.nn.sigmoid(g)
    w = jnp.exp(-jnp.exp(att["decay_log"]))
    u = att["first"]
    out, new_state = kns.wkv5_step(r.reshape(h, s), k.reshape(h, s), v.reshape(h, s), w, u, wkv_state)
    out = out.reshape(cfg.dim)
    out = _group_norm_heads(out, att["ln_x"], h) * g
    return _proj(out, att["wo"], kns), new_state


def _chan_mix_step(x, ffn_x_prev, ffn: Params, cfg: ModelConfig, impl: str):
    kns = kernels.get(impl)
    xk = _lerp(x, ffn_x_prev, ffn["mu_k"])
    xr = _lerp(x, ffn_x_prev, ffn["mu_r"])
    r = jax.nn.sigmoid(_proj(xr, ffn["wr"], kns))
    return r * kns.sqrelu_ffn(xk, ffn["wk"], ffn["wv"])


def block_step(params_block: Params, cfg: ModelConfig, x, att_x, wkv, ffn_x, impl: str = "jnp"):
    """One RWKV block on one token. Returns (x_out, att_x', wkv', ffn_x')."""
    xa = _ln(x, params_block["ln1"])
    dx, wkv = _time_mix_step(xa, att_x, wkv, params_block["att"], cfg, impl)
    x = x + dx
    xf = _ln(x, params_block["ln2"])
    x = x + _chan_mix_step(xf, ffn_x, params_block["ffn"], cfg, impl)
    return x, xa, wkv, xf


def step(params: Params, cfg: ModelConfig, x_emb, state: Params, impl: str = "jnp"):
    """Full-model decode step from an embedding vector.

    x_emb: (D,) the (possibly cache-served) embedding of the current token.
    Returns (logits (V,), new_state).  The embedding lookup and the head
    are OUTSIDE this function on purpose: at inference time the rust L3
    owns them (embedding cache §3.3, hierarchical head §3.3).
    """
    x = _ln(x_emb, params["ln0"])
    att_xs, wkvs, ffn_xs = [], [], []
    for i, block in enumerate(params["blocks"]):
        x, ax, wk, fx = block_step(
            block, cfg, x, state["att_x"][i], state["wkv"][i], state["ffn_x"][i], impl
        )
        att_xs.append(ax)
        wkvs.append(wk)
        ffn_xs.append(fx)
    x = _ln(x, params["ln_out"])
    new_state = {
        "att_x": jnp.stack(att_xs),
        "wkv": jnp.stack(wkvs),
        "ffn_x": jnp.stack(ffn_xs),
    }
    return x, new_state


def logits_from_hidden(params: Params, hidden) -> jnp.ndarray:
    """Dense head (used when the hierarchical head is disabled)."""
    return hidden @ params["head"]


# ---------------------------------------------------------------------------
# Introspection used by Table 1 / export
# ---------------------------------------------------------------------------


def param_groups(params: Params, cfg: ModelConfig) -> Dict[str, int]:
    """Parameter counts by the paper's Table 1 grouping."""

    def size(x):
        return int(np.prod(np.asarray(x).shape))

    def proj_size(p):
        return sum(size(v) for v in p.values())

    sq = nonsq = other = 0
    for b in params["blocks"]:
        att, ffn = b["att"], b["ffn"]
        sq += sum(proj_size(att[k]) for k in ("wr", "wk", "wv", "wg", "wo"))
        sq += proj_size(ffn["wr"])
        nonsq += size(ffn["wk"]) + size(ffn["wv"])
        other += sum(
            size(att[k]) for k in ("mu_r", "mu_k", "mu_v", "mu_g", "decay_log", "first")
        )
        other += size(ffn["mu_k"]) + size(ffn["mu_r"])
        for ln in (b["ln1"], b["ln2"], att["ln_x"]):
            other += size(ln["scale"]) + size(ln["bias"])
    head = size(params["head"])
    emb = size(params["emb"])
    other += sum(size(params[k][f]) for k in ("ln0", "ln_out") for f in ("scale", "bias"))
    return {"square": sq, "non_square": nonsq, "head": head, "emb": emb, "other": other}
