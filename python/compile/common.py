"""Shared helpers for the RWKV-Lite compile path (build-time only).

Everything in python/ runs at `make artifacts` time; nothing here is on the
inference request path (that is the rust coordinator).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict

import numpy as np

# ---------------------------------------------------------------------------
# Model configurations
# ---------------------------------------------------------------------------

# Scaled-down counterparts of the paper's Table 2 variants.  The paper uses
# D in {768..2560}, L in {12..32}, V=65536; we scale dims so that the
# *parameter-distribution regime* of Table 1 is preserved (emb+head dominate
# the tiny model, RWKV blocks dominate medium/regular) while everything
# trains in minutes on CPU.  head_size is fixed (paper: 64; ours: 16).
HEAD_SIZE = 16
FFN_MULT = 3.5  # channel-mix hidden dim = 3.5 * D, as in the paper

VARIANTS: Dict[str, Dict[str, int]] = {
    "tiny": dict(dim=64, layers=2),
    "small": dict(dim=128, layers=4),
    "medium": dict(dim=192, layers=6),
    "regular": dict(dim=256, layers=8),
}

VOCAB_SIZE = 1024  # scaled from the paper's 65536


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of one model variant (RWKV or transformer)."""

    arch: str  # "rwkv" | "rwkv_lite" | "transformer"
    variant: str  # tiny | small | medium | regular
    dim: int
    layers: int
    vocab: int = VOCAB_SIZE
    head_size: int = HEAD_SIZE
    # RWKV-Lite knobs (ignored by vanilla / transformer):
    svd_rank_div: int = 0  # k in the paper; 0 = no SVD decomposition
    enhanced_svd: bool = False  # Eq. 2 construct (pretrain-from-scratch)

    @property
    def heads(self) -> int:
        assert self.dim % self.head_size == 0
        return self.dim // self.head_size

    @property
    def ffn_dim(self) -> int:
        f = int(self.dim * FFN_MULT)
        assert f == self.dim * FFN_MULT, "FFN dim must be integral"
        return f

    @property
    def svd_rank(self) -> int:
        assert self.svd_rank_div > 0
        return max(1, self.dim // self.svd_rank_div)

    @property
    def name(self) -> str:
        tag = self.arch
        if self.svd_rank_div:
            tag += f"-svd{self.svd_rank_div}"
        if self.enhanced_svd:
            tag += "e"
        return f"{tag}-{self.variant}"

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def rwkv_config(variant: str, **kw: Any) -> ModelConfig:
    v = VARIANTS[variant]
    return ModelConfig(arch="rwkv", variant=variant, dim=v["dim"], layers=v["layers"], **kw)


def transformer_config(variant: str) -> ModelConfig:
    v = VARIANTS[variant]
    return ModelConfig(arch="transformer", variant=variant, dim=v["dim"], layers=v["layers"])


# ---------------------------------------------------------------------------
# Deterministic RNG + small utilities
# ---------------------------------------------------------------------------


def rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(np.random.PCG64(seed))


def orthogonal_init(g: np.random.Generator, shape, gain: float = 1.0) -> np.ndarray:
    """Orthogonal init as used by the official RWKV trainer for projections."""
    rows, cols = shape
    a = g.standard_normal((max(rows, cols), min(rows, cols)))
    q, r = np.linalg.qr(a)
    q = q * np.sign(np.diag(r))
    q = q[:rows, :cols] if rows >= cols else q.T[:rows, :cols]
    return (gain * q).astype(np.float32)


def tree_size(tree: Any) -> int:
    """Total number of scalar parameters in a pytree of arrays."""
    total = 0
    for leaf in tree_leaves(tree):
        total += int(np.prod(leaf.shape)) if hasattr(leaf, "shape") else 1
    return total


def tree_leaves(tree: Any):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from tree_leaves(tree[k])
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            yield from tree_leaves(v)
    else:
        yield tree


def repo_root() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(here))


def artifacts_dir(*parts: str) -> str:
    d = os.path.join(repo_root(), "artifacts", *parts)
    os.makedirs(d, exist_ok=True)
    return d


def save_json(path: str, obj: Any) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, sort_keys=True)


def env_flag(name: str, default: int) -> int:
    return int(os.environ.get(name, default))
