"""The `make artifacts` entry point: train -> compress -> export everything.

Runs ONCE at build time; the rust binary is self-contained afterwards.

    python -m compile.pipeline [--smoke] [--only <model-substr>] [--force]

Outputs (all under artifacts/):
    data/vocab.json        word list (token id = index)
    data/corpus.bin        i32 LE token stream (fig3 / bench prompts)
    data/tasks.json        benchmark suites (Table 5 analogs)
    ckpt/<name>.npz        trained parameter cache (skip re-training)
    models/<name>.rkv[.json]        fp16 checkpoints + manifests
    models/<name>-int8.rkv[.json]   int8 checkpoints
    hlo/<name>_{timemix,chanmix,head}.hlo.txt   AOT components
    training_report.json   loss curves, sparsity profiles, predictor stats

Environment: RWKV_LITE_STEPS_SCALE (float, default 1.0) scales every
training length; --smoke = scale 0.02 + tiny model only (used by pytest).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Any, Dict, List, Optional

import numpy as np

from . import aot, export, train
from .common import ModelConfig, artifacts_dir, rwkv_config, transformer_config, save_json
from .compress import heads, quant, sparsity, svd
from .data import corpus
from .models import rwkv, transformer

SIZES = ("tiny", "small", "medium")

PRETRAIN_STEPS = {"tiny": 300, "small": 350, "medium": 300, "regular": 200}
CONTINUAL_STEPS = {"tiny": 150, "small": 180, "medium": 150, "regular": 100}


# ---------------------------------------------------------------------------
# Checkpoint cache
# ---------------------------------------------------------------------------


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, list):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if isinstance(node, dict):
            keys = list(node.keys())
            if keys and all(k.isdigit() for k in keys):
                return [fix(node[str(i)]) for i in range(len(keys))]
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


def save_ckpt(path: str, params: Any) -> None:
    np.savez_compressed(path, **_flatten(params))


def load_ckpt(path: str) -> Any:
    with np.load(path) as z:
        return _unflatten({k: z[k] for k in z.files})


# ---------------------------------------------------------------------------
# Pipeline stages
# ---------------------------------------------------------------------------


class Pipeline:
    def __init__(self, scale: float = 1.0, only: Optional[str] = None, force: bool = False,
                 sizes=SIZES, with_regular: bool = False):
        self.scale = scale
        self.only = only
        self.force = force
        self.sizes = list(sizes)
        if with_regular:
            self.sizes.append("regular")
        self.report: Dict[str, Any] = {"models": {}}
        self.ckpt_dir = artifacts_dir("ckpt")
        self.model_dir = artifacts_dir("models")
        self.hlo_dir = artifacts_dir("hlo")
        self.data_dir = artifacts_dir("data")

    def steps(self, table: Dict[str, int], size: str) -> int:
        return max(5, int(table[size] * self.scale))

    def want(self, name: str) -> bool:
        return self.only is None or self.only in name

    # -- data ---------------------------------------------------------------

    def build_data(self):
        self.vocab, self.classes = corpus.build_vocab()
        save_json(os.path.join(self.data_dir, "vocab.json"), {"words": self.vocab.words})
        tok_path = os.path.join(self.data_dir, "corpus.bin")
        n_tok = int(200_000 * max(0.05, min(1.0, self.scale)))
        self.tokens = corpus.training_tokens(self.vocab, self.classes, n_tok)
        self.tokens.astype("<i4").tofile(tok_path)
        n_task = max(20, int(200 * min(1.0, self.scale)))
        self.tasks = corpus.make_tasks(self.vocab, self.classes, n_per_task=n_task)
        save_json(os.path.join(self.data_dir, "tasks.json"), self.tasks)
        print(f"[data] corpus={len(self.tokens)} tokens, tasks x{n_task}", flush=True)

    # -- training -----------------------------------------------------------

    def train_or_load(self, name: str, cfg: ModelConfig, init_params, forward_fn, steps: int,
                      base_lr: float = 3e-3) -> Any:
        path = os.path.join(self.ckpt_dir, f"{name}.npz")
        if os.path.exists(path) and not self.force:
            print(f"[train] {name}: cached", flush=True)
            return load_ckpt(path)
        t0 = time.time()
        params, losses = train.train_lm(
            forward_fn, init_params, cfg, self.tokens, steps=steps, tag=name, base_lr=base_lr
        )
        save_ckpt(path, params)
        self.report["models"].setdefault(name, {})["loss_curve"] = losses[:: max(1, len(losses) // 100)]
        self.report["models"][name]["train_seconds"] = time.time() - t0
        return params

    # -- compression attachments ---------------------------------------------

    def attach(self, name: str, params, cfg: ModelConfig):
        """Predictors + shadows + hierarchical head for an RWKV model."""
        acts = sparsity.collect_activations(params, cfg, self.tokens,
                                            n_samples=max(512, int(5000 * min(1.0, self.scale))))
        profile = sparsity.sparsity_profile(acts)
        preds = sparsity.init_predictors(cfg)
        epochs = max(3, int(50 * min(1.0, self.scale)))
        preds = sparsity.train_predictors(preds, acts, epochs=epochs, verbose=False)
        shadows = sparsity.build_shadow(params, bits=1)
        shadows4 = sparsity.build_shadow(params, bits=4)
        stats = sparsity.ensemble_stats(params, cfg, preds, shadows, acts)
        centroids, assign = heads.cluster_embeddings(params)
        hiddens = heads.sample_hiddens(params, cfg, self.tokens,
                                       n_samples=max(512, int(4000 * min(1.0, self.scale))))
        h1 = heads.train_cluster_head(params, cfg, assign, hiddens,
                                      epochs=max(3, int(30 * min(1.0, self.scale))), verbose=False)
        coverage = heads.head_coverage(params, cfg, h1, assign, hiddens)
        self.report["models"].setdefault(name, {}).update(
            sparsity_profile=profile,
            predictor_stats=stats,
            hh_coverage=coverage,
        )
        print(f"[attach] {name}: sparsity={['%.2f' % s for s in profile]}, "
              f"hh_cov={coverage['argmax_coverage']:.2f}", flush=True)
        return preds, (shadows, shadows4), {"h1": h1, "assign": assign}

    def export_rwkv(self, name: str, params, cfg: ModelConfig, preds, shadows, hh):
        shadows1, shadows4 = shadows
        for precision in ("f16", "int8"):
            suffix = "" if precision == "f16" else "-int8"
            export.export_model(
                self.model_dir, name + suffix, params, cfg, precision,
                predictors=preds, shadows=shadows1, hier_head=hh, shadows4=shadows4,
                extra_manifest={"hlo": self.hlo_manifests.get(name, {}),
                                "arch_family": "rwkv"},
            )
        print(f"[export] {name} (+int8)", flush=True)

    # -- main ----------------------------------------------------------------

    def run(self):
        t_start = time.time()
        self.build_data()
        self.hlo_manifests: Dict[str, Any] = {}

        for size in self.sizes:
            # 1. vanilla RWKV (= the paper's inhouse-vanilla; we have no
            #    official 1.1T-token checkpoints — DESIGN.md §2).
            vname = f"rwkv-vanilla-{size}"
            if self.want(vname):
                cfg = rwkv_config(size)
                params = self.train_or_load(vname, cfg, rwkv.init(cfg, seed=1),
                                            rwkv.forward, self.steps(PRETRAIN_STEPS, size))
                self.hlo_manifests[vname] = aot.lower_model_components(
                    params, cfg, vname, self.hlo_dir)
                preds, shadows, hh = self.attach(vname, params, cfg)
                self.export_rwkv(vname, params, cfg, preds, shadows, hh)
                self._eval(vname, params, cfg, rwkv.forward)
                vanilla_params, vanilla_cfg = params, cfg

            # 2. RWKV-ours: SVD(k=8) decomposition + continual training.
            oname = f"rwkv-ours-{size}"
            if self.want(oname):
                cfg8 = rwkv_config(size, svd_rank_div=8)
                ckpt = os.path.join(self.ckpt_dir, f"{oname}.npz")
                if os.path.exists(ckpt) and not self.force:
                    params = load_ckpt(ckpt)
                    print(f"[train] {oname}: cached", flush=True)
                else:
                    init_p = svd.decompose_model(vanilla_params, cfg8)
                    params = self.train_or_load(oname, cfg8, init_p, rwkv.forward,
                                                self.steps(CONTINUAL_STEPS, size), base_lr=1e-3)
                self.hlo_manifests[oname] = aot.lower_model_components(
                    params, cfg8, oname, self.hlo_dir)
                preds, shadows, hh = self.attach(oname, params, cfg8)
                self.export_rwkv(oname, params, cfg8, preds, shadows, hh)
                self._eval(oname, params, cfg8, rwkv.forward)

            # 3. inhouse-ours: enhanced SVD (Eq. 2), pretrained from scratch.
            pname = f"rwkv-pre-{size}"
            if self.want(pname):
                cfge = rwkv_config(size, svd_rank_div=8, enhanced_svd=True)
                params = self.train_or_load(pname, cfge, rwkv.init(cfge, seed=2),
                                            rwkv.forward, self.steps(PRETRAIN_STEPS, size))
                self.hlo_manifests[pname] = aot.lower_model_components(
                    params, cfge, pname, self.hlo_dir)
                preds, shadows, hh = self.attach(pname, params, cfge)
                self.export_rwkv(pname, params, cfge, preds, shadows, hh)
                self._eval(pname, params, cfge, rwkv.forward)

            # 4. transformer baseline (OPT/GPT-Neo/TinyLlama stand-in).
            tname = f"gpt-{size}"
            if self.want(tname):
                tcfg = transformer_config(size)
                params = self.train_or_load(tname, tcfg, transformer.init(tcfg, seed=3),
                                            transformer.forward, self.steps(PRETRAIN_STEPS, size))
                for precision in ("f16", "int8"):
                    suffix = "" if precision == "f16" else "-int8"
                    export.export_transformer(self.model_dir, tname + suffix, params, tcfg,
                                              precision, extra_manifest={"arch_family": "transformer"})
                self._eval(tname, params, tcfg, transformer.forward)
                print(f"[export] {tname} (+int8)", flush=True)

        # 5. SVD factor sweep (§B.4): k in {4, 16} on the small model.
        for k in (4, 16):
            sname = f"rwkv-ours-k{k}-small"
            if self.want(sname) and "small" in self.sizes:
                cfgk = rwkv_config("small", svd_rank_div=k)
                ckpt = os.path.join(self.ckpt_dir, f"{sname}.npz")
                if os.path.exists(ckpt) and not self.force:
                    params = load_ckpt(ckpt)
                else:
                    base = load_ckpt(os.path.join(self.ckpt_dir, "rwkv-vanilla-small.npz"))
                    init_p = svd.decompose_model(base, cfgk)
                    params = self.train_or_load(sname, cfgk, init_p, rwkv.forward,
                                                self.steps(CONTINUAL_STEPS, "small"), base_lr=1e-3)
                self.hlo_manifests[sname] = aot.lower_model_components(params, cfgk, sname, self.hlo_dir)
                preds, shadows, hh = self.attach(sname, params, cfgk)
                self.export_rwkv(sname, params, cfgk, preds, shadows, hh)
                self._eval(sname, params, cfgk, rwkv.forward)

        save_json(os.path.join(artifacts_dir(), "training_report.json"), self.report)
        # Build stamp consumed by the Makefile's incremental check.
        with open(os.path.join(artifacts_dir(), ".stamp"), "w") as f:
            f.write(f"{time.time()}\n")
        print(f"[pipeline] done in {time.time() - t_start:.0f}s", flush=True)

    def _eval(self, name: str, params, cfg: ModelConfig, forward_fn):
        sub = {"lambada_syn": self.tasks["lambada_syn"][:100]}
        res = train.eval_tasks(forward_fn, params, cfg, sub)
        self.report["models"].setdefault(name, {})["eval"] = res
        print(f"[eval] {name}: {res}", flush=True)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny-only, 2%% steps (tests)")
    ap.add_argument("--only", default=None, help="substring filter on model names")
    ap.add_argument("--force", action="store_true", help="retrain even if cached")
    ap.add_argument("--regular", action="store_true", help="also build the 3B-analog size")
    args = ap.parse_args(argv)
    scale = float(os.environ.get("RWKV_LITE_STEPS_SCALE", "1.0"))
    sizes = SIZES
    if args.smoke:
        scale, sizes = 0.02, ("tiny",)
    Pipeline(scale=scale, only=args.only, force=args.force, sizes=sizes,
             with_regular=args.regular).run()
    return 0


if __name__ == "__main__":
    sys.exit(main())
