"""AOT lowering: L2 jax functions (calling L1 Pallas kernels) -> HLO text.

HLO *text* is the interchange format (NOT `lowered.serialize()` /
serialized HloModuleProto): jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published `xla` 0.1.6 crate
links) rejects (`proto.id() <= INT_MAX`).  The text parser reassigns ids,
so text round-trips cleanly.  See /opt/xla-example/README.md.

Per exported model we lower three components, each with *weights as runtime
parameters* so the rust L3 keeps ownership of weight residency (loading
strategies / sparse loading would be impossible with weights baked into the
executable):

  timemix_step   (x, att_x, wkv, <ordered weights>) -> (x', att_x', wkv')
  chanmix_step   (x, ffn_x, <ordered weights>)      -> (x', ffn_x')
  head_matvec    (hidden, head)                     -> (logits,)

One executable per (variant-shape, component); the same executable is
reused for every layer (weights differ per call, shapes do not).  The
parameter *order* for each component is recorded in the model manifest so
rust maps `.rkv` tensor names -> argument positions.
"""

from __future__ import annotations

import os
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .common import ModelConfig
from .models import rwkv


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Parameter ordering
# ---------------------------------------------------------------------------


def proj_keys(p: Dict[str, Any]) -> List[str]:
    return [k for k in ("w", "l", "r", "d") if k in p]


def timemix_weight_names(block: Dict[str, Any]) -> List[str]:
    names = ["ln1.scale", "ln1.bias", "att.mu_r", "att.mu_k", "att.mu_v", "att.mu_g", "att.decay", "att.first"]
    for w in ("wr", "wk", "wv", "wg"):
        names += [f"att.{w}.{k}" for k in proj_keys(block["att"][w])]
    names += ["att.wo.w", "att.lnx.scale", "att.lnx.bias"]
    return names


def chanmix_weight_names(block: Dict[str, Any]) -> List[str]:
    names = ["ln2.scale", "ln2.bias", "ffn.mu_k", "ffn.mu_r"]
    names += [f"ffn.wr.{k}" for k in proj_keys(block["ffn"]["wr"])]
    # wk is consumed transposed (F, D) to match the .rkv layout (export.py).
    names += ["ffn.wk_t", "ffn.wv"]
    return names


def _get_block_tensor(block: Dict[str, Any], name: str) -> np.ndarray:
    """Resolve a component weight name against a block pytree."""
    parts = name.split(".")
    if parts[0] in ("ln1", "ln2"):
        return np.asarray(block[parts[0]][parts[1]])
    scope, rest = parts[0], parts[1:]
    node = block[scope]
    if rest[0] == "decay":
        return np.exp(-np.exp(np.asarray(node["decay_log"], np.float32)))
    if rest[0] == "lnx":
        return np.asarray(node["ln_x"][rest[1]])
    if rest[0].startswith("mu_") or rest[0] == "first":
        return np.asarray(node[rest[0]])
    if rest[0] == "wk_t":
        return np.ascontiguousarray(np.asarray(node["wk"]).T)
    if len(rest) == 2:  # projection leaf e.g. wr.l
        return np.asarray(node[rest[0]][rest[1]])
    return np.asarray(node[rest[0]])  # dense matrix e.g. wv


# ---------------------------------------------------------------------------
# Component functions (impl = pallas so the L1 kernels ship in the HLO)
# ---------------------------------------------------------------------------


def _rebuild_proj(names: List[str], args: List[Any], prefix: str) -> Dict[str, Any]:
    return {
        n.split(".")[-1]: args[i]
        for i, n in enumerate(names)
        if n.startswith(prefix + ".")
    }


def make_timemix_fn(cfg: ModelConfig, names: List[str], impl: str = "pallas") -> Callable:
    h, s = cfg.heads, cfg.head_size

    def fn(x, att_x, wkv, *weights):
        get = lambda n: weights[names.index(n)]  # noqa: E731
        kns = kernels.get(impl)
        ln1 = {"scale": get("ln1.scale"), "bias": get("ln1.bias")}
        xa = rwkv._ln(x, ln1)
        projs = {w: _rebuild_proj(names, list(weights), f"att.{w}") for w in ("wr", "wk", "wv", "wg")}
        r = rwkv._proj(rwkv._lerp(xa, att_x, get("att.mu_r")), projs["wr"], kns)
        k = rwkv._proj(rwkv._lerp(xa, att_x, get("att.mu_k")), projs["wk"], kns)
        v = rwkv._proj(rwkv._lerp(xa, att_x, get("att.mu_v")), projs["wv"], kns)
        g = rwkv._proj(rwkv._lerp(xa, att_x, get("att.mu_g")), projs["wg"], kns)
        g = g * jax.nn.sigmoid(g)
        out, new_wkv = kns.wkv5_step(
            r.reshape(h, s), k.reshape(h, s), v.reshape(h, s),
            get("att.decay"), get("att.first"), wkv,
        )
        out = out.reshape(cfg.dim)
        lnx = {"scale": get("att.lnx.scale"), "bias": get("att.lnx.bias")}
        out = rwkv._group_norm_heads(out, lnx, h) * g
        x_out = x + out @ get("att.wo.w")
        return x_out, xa, new_wkv

    return fn


def make_chanmix_fn(cfg: ModelConfig, names: List[str], impl: str = "pallas") -> Callable:
    def fn(x, ffn_x, *weights):
        get = lambda n: weights[names.index(n)]  # noqa: E731
        kns = kernels.get(impl)
        ln2 = {"scale": get("ln2.scale"), "bias": get("ln2.bias")}
        xf = rwkv._ln(x, ln2)
        xk = rwkv._lerp(xf, ffn_x, get("ffn.mu_k"))
        xr = rwkv._lerp(xf, ffn_x, get("ffn.mu_r"))
        wr = _rebuild_proj(names, list(weights), "ffn.wr")
        r = jax.nn.sigmoid(rwkv._proj(xr, wr, kns))
        # wk arrives transposed (F, D); XLA folds the transpose into the dot.
        x_out = x + r * kns.sqrelu_ffn(xk, get("ffn.wk_t").T, get("ffn.wv"))
        return x_out, xf

    return fn


def head_matvec_fn(hidden, head_t):
    # head arrives transposed (V, D) to match the .rkv layout (export.py).
    return (head_t @ hidden,)


# ---------------------------------------------------------------------------
# Lowering driver
# ---------------------------------------------------------------------------


def _spec(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_model_components(
    params: Dict[str, Any], cfg: ModelConfig, name: str, out_dir: str, impl: str = "pallas"
) -> Dict[str, Any]:
    """Lower the three components; write `<name>_<component>.hlo.txt`.

    Returns the AOT manifest fragment {component: {params: [...], path}}.
    """
    os.makedirs(out_dir, exist_ok=True)
    d, h, s, f, v = cfg.dim, cfg.heads, cfg.head_size, cfg.ffn_dim, cfg.vocab
    block0 = params["blocks"][0]
    manifest: Dict[str, Any] = {}

    tm_names = timemix_weight_names(block0)
    tm_fn = make_timemix_fn(cfg, tm_names, impl)
    tm_specs = [_spec((d,)), _spec((d,)), _spec((h, s, s))] + [
        _spec(_get_block_tensor(block0, n).shape) for n in tm_names
    ]
    lowered = jax.jit(tm_fn).lower(*tm_specs)
    path = os.path.join(out_dir, f"{name}_timemix.hlo.txt")
    with open(path, "w") as fp:
        fp.write(to_hlo_text(lowered))
    manifest["timemix"] = {"params": tm_names, "path": os.path.basename(path)}

    cm_names = chanmix_weight_names(block0)
    cm_fn = make_chanmix_fn(cfg, cm_names, impl)
    cm_specs = [_spec((d,)), _spec((d,))] + [
        _spec(_get_block_tensor(block0, n).shape) for n in cm_names
    ]
    lowered = jax.jit(cm_fn).lower(*cm_specs)
    path = os.path.join(out_dir, f"{name}_chanmix.hlo.txt")
    with open(path, "w") as fp:
        fp.write(to_hlo_text(lowered))
    manifest["chanmix"] = {"params": cm_names, "path": os.path.basename(path)}

    lowered = jax.jit(head_matvec_fn).lower(_spec((d,)), _spec((v, d)))
    path = os.path.join(out_dir, f"{name}_head.hlo.txt")
    with open(path, "w") as fp:
        fp.write(to_hlo_text(lowered))
    manifest["head"] = {"params": ["head"], "path": os.path.basename(path)}

    return manifest


# Smoke-check helper used by tests: run the lowered fns in-process.
def run_component_reference(params, cfg: ModelConfig, x, state) -> Tuple[np.ndarray, Dict[str, Any]]:
    """Execute one full step via the component fns (jnp impl) for parity tests."""
    block_outs = []
    xcur = rwkv._ln(jnp.asarray(x), params["ln0"])
    att_xs, wkvs, ffn_xs = [], [], []
    for i, block in enumerate(params["blocks"]):
        tm_names = timemix_weight_names(block)
        tm_fn = make_timemix_fn(cfg, tm_names, impl="jnp")
        weights = [jnp.asarray(_get_block_tensor(block, n)) for n in tm_names]
        xcur, ax, wk = tm_fn(xcur, state["att_x"][i], state["wkv"][i], *weights)
        cm_names = chanmix_weight_names(block)
        cm_fn = make_chanmix_fn(cfg, cm_names, impl="jnp")
        weights = [jnp.asarray(_get_block_tensor(block, n)) for n in cm_names]
        xcur, fx = cm_fn(xcur, state["ffn_x"][i], *weights)
        att_xs.append(ax)
        wkvs.append(wk)
        ffn_xs.append(fx)
        block_outs.append(xcur)
    hidden = rwkv._ln(xcur, params["ln_out"])
    new_state = {"att_x": jnp.stack(att_xs), "wkv": jnp.stack(wkvs), "ffn_x": jnp.stack(ffn_xs)}
    return np.asarray(hidden), new_state
