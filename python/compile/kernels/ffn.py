"""Pallas kernels for the channel-mix FFN (squared-ReLU, optionally masked).

The FFN is where the paper's sparsity technique (§3.2) bites: given the
predictor mask, only the selected columns of W_k / rows of W_v participate.
On the TPU side we do NOT gather (random-access gathers waste MXU cycles);
instead the host (rust L3) compacts the selected rows into a dense buffer
and calls the *dense* kernel on the compacted operands — identical math,
dense tiles.  The masked kernel below exists for the L2 training/eval graph
where the mask is applied in-graph.

Tiling: grid over F (the 3.5*D hidden dim) in TILE_F chunks; each grid step
computes a (TILE_F,) slice of the squared-ReLU activation and accumulates
its contribution to the (D,) output — the classic reduce-over-grid pattern
with the accumulator tile resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_F = 128


def _ffn_kernel(x_ref, wk_ref, wv_ref, o_ref):
    """Grid step i: h_i = relu(x @ wk[:, i])^2 ; o += h_i @ wv[i, :]."""
    i = pl.program_id(0)
    x = x_ref[...]  # (1, D)
    h = jnp.maximum(x @ wk_ref[...], 0.0)  # (1, TILE_F)
    contrib = (h * h) @ wv_ref[...]  # (1, D)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += contrib


def _ffn_masked_kernel(x_ref, wk_ref, wv_ref, m_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...]
    h = jnp.maximum(x @ wk_ref[...], 0.0) * m_ref[...]
    contrib = (h * h) @ wv_ref[...]

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += contrib


def _grid_f(f: int) -> int:
    assert f % _tile(f) == 0
    return f // _tile(f)


def _tile(f: int) -> int:
    # Shrink the tile for toy dims so the grid is still >= 2 (exercises the
    # accumulator path); production dims use TILE_F.
    t = TILE_F
    while f % t != 0 or f // t < 2:
        t //= 2
        if t < 8:
            return f  # degenerate: single tile
    return t


@functools.partial(jax.jit, static_argnames=("interpret",))
def sqrelu_ffn(x, wk, wv, mask=None, interpret: bool = True):
    """Pallas squared-ReLU FFN.  x: (1, D) or (D,); wk: (D, F); wv: (F, D)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    d, f = wk.shape
    tf = _tile(f)
    grid = (f // tf,)
    xs = pl.BlockSpec((1, d), lambda i: (0, 0))
    wks = pl.BlockSpec((d, tf), lambda i: (0, i))
    wvs = pl.BlockSpec((tf, d), lambda i: (i, 0))
    os = pl.BlockSpec((1, d), lambda i: (0, 0))
    if mask is None:
        out = pl.pallas_call(
            _ffn_kernel,
            grid=grid,
            in_specs=[xs, wks, wvs],
            out_specs=os,
            out_shape=jax.ShapeDtypeStruct((1, d), x.dtype),
            interpret=interpret,
        )(x, wk, wv)
    else:
        if mask.ndim == 1:
            mask = mask[None, :]
        ms = pl.BlockSpec((1, tf), lambda i: (0, i))
        out = pl.pallas_call(
            _ffn_masked_kernel,
            grid=grid,
            in_specs=[xs, wks, wvs, ms],
            out_specs=os,
            out_shape=jax.ShapeDtypeStruct((1, d), x.dtype),
            interpret=interpret,
        )(x, wk, wv, mask.astype(x.dtype))
    return out[0] if squeeze else out
