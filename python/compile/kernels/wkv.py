"""Pallas kernel for the RWKV-v5 WKV recurrence (the model's hot spot).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the official RWKV CUDA
kernel assigns one threadblock per (batch, head) and keeps the (S, S) state
in shared memory.  On TPU we express the same locality with a BlockSpec grid
over heads: each grid step owns one head's (S, S) state tile in VMEM, the
outer-product update and the r-contraction both map onto the MXU/VPU, and
the HBM<->VMEM schedule is carried by the BlockSpec instead of explicit
smem loads.

Kernels here are lowered with `interpret=True` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; numerics are validated through the interpret
path against `ref.py` (python/tests/test_kernels.py), and real-TPU
efficiency is estimated analytically (DESIGN.md §8, EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv5_step_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s_ref, o_ref, s_out_ref):
    """One head per grid step: in-VMEM state update + output contraction.

    Block shapes: r/k/v/w/u are (1, S); state is (1, S, S).
    """
    r = r_ref[0, :]
    k = k_ref[0, :]
    v = v_ref[0, :]
    w = w_ref[0, :]
    u = u_ref[0, :]
    s = s_ref[0, :, :]
    # a[i, j] = k[i] * v[j]  — rank-1 update, VPU-friendly broadcast.
    a = k[:, None] * v[None, :]
    # out[j] = sum_i r[i] * (u[i] * a[i, j] + s[i, j])  — (1,S)x(S,S) matvec.
    o_ref[0, :] = (r[:, None] * (u[:, None] * a + s)).sum(axis=0)
    s_out_ref[0, :, :] = w[:, None] * s + a


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv5_step(r, k, v, w, u, state, interpret: bool = True):
    """Pallas WKV decode step. Shapes as in ref.wkv5_step: (H,S) / (H,S,S)."""
    h, s = r.shape
    vec = pl.BlockSpec((1, s), lambda i: (i, 0))
    mat = pl.BlockSpec((1, s, s), lambda i: (i, 0, 0))
    out, new_state = pl.pallas_call(
        _wkv5_step_kernel,
        grid=(h,),
        in_specs=[vec, vec, vec, vec, vec, mat],
        out_specs=[vec, mat],
        out_shape=[
            jax.ShapeDtypeStruct((h, s), r.dtype),
            jax.ShapeDtypeStruct((h, s, s), state.dtype),
        ],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return out, new_state


def _wkv5_seq_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref):
    """Prefill kernel: one head per grid step, fori_loop over time.

    The (S, S) state tile stays resident in VMEM for the whole sequence —
    the TPU analog of the CUDA kernel keeping state in shared memory across
    the token loop.  Block shapes: r/k/v are (T, 1, S); w/u (1, S); state
    (1, S, S); out (T, 1, S).
    """
    w = w_ref[0, :]
    u = u_ref[0, :]
    t_len = r_ref.shape[0]

    def body(t, s):
        r = r_ref[t, 0, :]
        k = k_ref[t, 0, :]
        v = v_ref[t, 0, :]
        a = k[:, None] * v[None, :]
        o_ref[t, 0, :] = (r[:, None] * (u[:, None] * a + s)).sum(axis=0)
        return w[:, None] * s + a

    s_final = jax.lax.fori_loop(0, t_len, body, s0_ref[0, :, :])
    sT_ref[0, :, :] = s_final


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv5_seq(r, k, v, w, u, state, interpret: bool = True):
    """Pallas WKV over a sequence. r/k/v: (T, H, S); returns ((T,H,S), (H,S,S))."""
    t, h, s = r.shape
    seq = pl.BlockSpec((t, 1, s), lambda i: (0, i, 0))
    vec = pl.BlockSpec((1, s), lambda i: (i, 0))
    mat = pl.BlockSpec((1, s, s), lambda i: (i, 0, 0))
    out, s_t = pl.pallas_call(
        _wkv5_seq_kernel,
        grid=(h,),
        in_specs=[seq, seq, seq, vec, vec, mat],
        out_specs=[seq, mat],
        out_shape=[
            jax.ShapeDtypeStruct((t, h, s), r.dtype),
            jax.ShapeDtypeStruct((h, s, s), state.dtype),
        ],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return out, s_t


def vmem_bytes(heads: int, head_size: int, t: int, dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM footprint estimate for the seq kernel (DESIGN.md §8)."""
    state = head_size * head_size * dtype_bytes
    streams = 4 * t * head_size * dtype_bytes  # r, k, v, o
    consts = 2 * head_size * dtype_bytes  # w, u
    return state + streams + consts
