"""Pallas kernels for the SVD-decomposed projections (paper §3.1).

Two constructs:
  * simple:   x @ W  ≈ (x @ L) @ R                      (Eq. 1)
  * enhanced: x @ W  ≈ relu(x @ L)^2 @ R + x * diag(D)  (Eq. 2)

Both are two chained matvecs with a tiny intermediate (rank M/k).  The TPU
mapping keeps the (D, r) L tile and (r, D) R tile in VMEM simultaneously —
for k=8 they are 4x smaller combined than the original W tile, so the
kernel is strictly friendlier to VMEM than the dense projection it
replaces (that is the paper's whole point, translated to tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lowrank_kernel(x_ref, l_ref, r_ref, o_ref):
    t = x_ref[...] @ l_ref[...]  # (1, rank)
    o_ref[...] = t @ r_ref[...]  # (1, D)


def _enhanced_kernel(x_ref, l_ref, r_ref, d_ref, o_ref):
    x = x_ref[...]
    t = jnp.maximum(x @ l_ref[...], 0.0)
    o_ref[...] = (t * t) @ r_ref[...] + x * d_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def lowrank_proj(x, l, r, interpret: bool = True):
    """x: (1, M) or (M,); l: (M, rank); r: (rank, N)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    m, rank = l.shape
    _, n = r.shape
    out = pl.pallas_call(
        _lowrank_kernel,
        in_specs=[
            pl.BlockSpec((1, m), lambda: (0, 0)),
            pl.BlockSpec((m, rank), lambda: (0, 0)),
            pl.BlockSpec((rank, n), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype),
        interpret=interpret,
    )(x, l, r)
    return out[0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("interpret",))
def enhanced_lowrank_proj(x, l, r, d, interpret: bool = True):
    """Enhanced-SVD projection; d: (N,) diagonal compensation (M == N)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    m, rank = l.shape
    _, n = r.shape
    dd = d[None, :] if d.ndim == 1 else d
    out = pl.pallas_call(
        _enhanced_kernel,
        in_specs=[
            pl.BlockSpec((1, m), lambda: (0, 0)),
            pl.BlockSpec((m, rank), lambda: (0, 0)),
            pl.BlockSpec((rank, n), lambda: (0, 0)),
            pl.BlockSpec((1, n), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype),
        interpret=interpret,
    )(x, l, r, dd)
    return out[0] if squeeze else out
