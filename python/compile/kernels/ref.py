"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth for pytest/hypothesis correctness sweeps
(python/tests/test_kernels.py) and are also the implementation used during
*training* (interpret-mode Pallas is much slower than fused jnp on CPU; the
AOT path routes through the Pallas kernels so the shipped HLO exercises L1).
"""

from __future__ import annotations

import jax.numpy as jnp


def wkv5_step(r, k, v, w, u, state):
    """One decode step of the RWKV-v5 multi-head WKV recurrence.

    Args:
      r, k, v: (H, S) receptance / key / value for this timestep.
      w:       (H, S) per-channel decay in (0, 1)  (i.e. exp(-exp(log_w))).
      u:       (H, S) per-channel "bonus" applied to the current token.
      state:   (H, S, S) running state; state[h, i, j] accumulates k_i * v_j.

    Returns:
      out:       (H, S) attention output per head.
      new_state: (H, S, S).
    """
    a = jnp.einsum("hi,hj->hij", k, v)  # outer product per head
    out = jnp.einsum("hi,hij->hj", r, u[..., None] * a + state)
    new_state = w[..., None] * state + a
    return out, new_state


def wkv5_seq(r, k, v, w, u, state):
    """Sequence form: r/k/v are (T, H, S); returns (T, H, S) and final state."""
    import jax

    def step(st, rkv):
        rt, kt, vt = rkv
        out, st = wkv5_step(rt, kt, vt, w, u, st)
        return st, out

    state, outs = jax.lax.scan(step, state, (r, k, v))
    return outs, state


def sqrelu_ffn(x, wk, wv, mask=None):
    """Channel-mix FFN: relu(x @ wk)^2 @ wv, optionally column-masked.

    x: (..., D); wk: (D, F); wv: (F, D); mask: (F,) in {0,1} — the sparsity
    predictor output (paper Eq. 3/5): masked columns of wk (and rows of wv)
    are never loaded, which the oracle models by zeroing the activation.
    """
    h = jnp.maximum(x @ wk, 0.0)
    if mask is not None:
        h = h * mask
    return (h * h) @ wv


def lowrank_proj(x, l, r):
    """Simple-SVD projection (paper Eq. 1): x @ W  ≈  (x @ L) @ R."""
    return (x @ l) @ r


def enhanced_lowrank_proj(x, l, r, d):
    """Enhanced-SVD projection (paper Eq. 2): relu(x@L)^2 @ R + x * d.

    d is the diagonal of the full-rank compensation matrix D.
    """
    h = jnp.maximum(x @ l, 0.0)
    return (h * h) @ r + x * d


def int8_matvec(x, wq, scale):
    """Fused dequant x (..., M) @ dequant(wq (M, N)) with per-column scale.

    The oracle dequantizes explicitly; the Pallas kernel keeps INT8 tiles in
    VMEM and folds `scale` into the accumulator (never materializing an f32
    copy of W in HBM) — the TPU analog of the paper's NEON fused kernels.
    """
    return (x @ wq.astype(jnp.float32)) * scale


def bitlinear_matvec(x, wsign, scale):
    """1-bit shadow-FFN score (the quantized sparsity predictor, Eq. 4).

    wsign: (M, N) in {-1, +1} (stored packed on the rust side); scale: (N,)
    per-column magnitude.  Output approximates x @ W.
    """
    return (x @ wsign.astype(jnp.float32)) * scale
