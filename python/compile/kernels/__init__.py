"""L1 Pallas kernels + pure-jnp oracles.

`impl="jnp"` (ref oracles, used for training speed) or `impl="pallas"`
(interpret-mode Pallas, used by the AOT lowering so the shipped HLO
exercises the L1 kernels).
"""

from . import ref
from .ffn import sqrelu_ffn as sqrelu_ffn_pallas
from .int8 import int8_matvec as int8_matvec_pallas
from .lowrank import enhanced_lowrank_proj as enhanced_lowrank_proj_pallas
from .lowrank import lowrank_proj as lowrank_proj_pallas
from .wkv import wkv5_seq as wkv5_seq_pallas
from .wkv import wkv5_step as wkv5_step_pallas


def get(impl: str):
    """Return the kernel namespace for `impl` in {"jnp", "pallas"}."""
    if impl == "jnp":
        return ref
    if impl == "pallas":
        return _PallasNS
    raise ValueError(f"unknown kernel impl: {impl}")


class _PallasNS:
    wkv5_step = staticmethod(wkv5_step_pallas)
    wkv5_seq = staticmethod(wkv5_seq_pallas)
    sqrelu_ffn = staticmethod(sqrelu_ffn_pallas)
    lowrank_proj = staticmethod(lowrank_proj_pallas)
    enhanced_lowrank_proj = staticmethod(enhanced_lowrank_proj_pallas)
    int8_matvec = staticmethod(int8_matvec_pallas)
