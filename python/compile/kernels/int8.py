"""Pallas fused INT8-dequant matvec — the TPU analog of the paper's NEON
fused dequant+matvec kernels (§4 "Custom ARM NEON kernels").

The paper's insight: dequantizing W to a separate buffer before the matvec
doubles memory traffic and trashes the cache; fusing dequant into the
multiply keeps traffic at 1 byte/weight.  The TPU mapping: INT8 weight
tiles stream HBM->VMEM at 1 byte/weight, are widened in-register, and the
per-column scale is folded into the accumulator after the contraction —
no f32 copy of W ever exists anywhere.

Grid over output columns (N) so the scale vector slice rides with its tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 128


def _int8_kernel(x_ref, wq_ref, s_ref, o_ref):
    x = x_ref[...]  # (1, M) f32
    w = wq_ref[...].astype(jnp.float32)  # (M, TILE_N) widened in-register
    o_ref[...] = (x @ w) * s_ref[...]


def _tile(n: int) -> int:
    t = TILE_N
    while n % t != 0:
        t //= 2
        if t < 8:
            return n
    return t


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_matvec(x, wq, scale, interpret: bool = True):
    """x: (1, M) or (M,) f32; wq: (M, N) int8; scale: (N,) f32 per-column."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    m, n = wq.shape
    tn = _tile(n)
    s2 = scale[None, :] if scale.ndim == 1 else scale
    out = pl.pallas_call(
        _int8_kernel,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((m, tn), lambda i: (0, i)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), wq, s2.astype(jnp.float32))
    return out[0] if squeeze else out
