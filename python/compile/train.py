"""Build-time trainer (substrate S6): AdamW + cosine schedule, pure JAX.

No optax/flax in this environment, so the optimizer is implemented here.
Training is CPU-scale by design (DESIGN.md §2): the paper's techniques are
architecture-level mechanisms; they demonstrate at scaled dims in minutes.
"""

from __future__ import annotations

import math
import time
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, rng

Params = Any


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params: Params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
    return {"m": zeros, "v": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params), "t": jnp.zeros((), jnp.int32)}


def adamw_update(params: Params, grads: Params, opt: Dict[str, Any], lr, b1=0.9, b2=0.99, eps=1e-8, wd=1e-4):
    t = opt["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return p - step - lr * wd * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def cosine_lr(step, total_steps, base=3e-3, warmup=20, floor=0.1):
    w = jnp.minimum(1.0, (step + 1) / warmup)
    prog = jnp.clip((step - warmup) / jnp.maximum(1, total_steps - warmup), 0.0, 1.0)
    return base * w * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(math.pi * prog)))


# ---------------------------------------------------------------------------
# LM training loop
# ---------------------------------------------------------------------------


def lm_loss(forward_fn: Callable, params: Params, cfg: ModelConfig, batch) -> jnp.ndarray:
    """Next-token cross entropy over a (B, T+1) token batch."""
    inp, tgt = batch[:, :-1], batch[:, 1:]
    logits = forward_fn(params, cfg, inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


def batches(tokens: np.ndarray, bsz: int, seqlen: int, steps: int, seed: int):
    g = rng(seed)
    n = len(tokens) - (seqlen + 1)
    for _ in range(steps):
        idx = g.integers(0, n, size=bsz)
        yield np.stack([tokens[i : i + seqlen + 1] for i in idx]).astype(np.int32)


def train_lm(
    forward_fn: Callable,
    params: Params,
    cfg: ModelConfig,
    tokens: np.ndarray,
    steps: int,
    bsz: int = 16,
    seqlen: int = 64,
    base_lr: float = 3e-3,
    seed: int = 42,
    log_every: int = 50,
    tag: str = "",
) -> Tuple[Params, List[float]]:
    """Train (or continually train) an LM; returns params + loss curve."""
    opt = adamw_init(params)

    @jax.jit
    def update(params, opt, batch, step):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(forward_fn, p, cfg, batch))(params)
        # global-norm clip at 1.0
        gnorm = jnp.sqrt(
            sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(grads))
        )
        scale = jnp.minimum(1.0, 1.0 / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        lr = cosine_lr(step, steps, base=base_lr)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    losses: List[float] = []
    t0 = time.time()
    for i, batch in enumerate(batches(tokens, bsz, seqlen, steps, seed)):
        params, opt, loss = update(params, opt, batch, jnp.asarray(i))
        losses.append(float(loss))
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(
                f"  [{tag}] step {i:4d}/{steps} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)",
                flush=True,
            )
    return params, losses


# ---------------------------------------------------------------------------
# Evaluation (python-side sanity; the reported numbers come from rust)
# ---------------------------------------------------------------------------


def _pad_batch(seqs: List[List[int]], pad: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    t = max(len(s) for s in seqs)
    out = np.full((len(seqs), t), pad, np.int32)
    lens = np.zeros(len(seqs), np.int32)
    for i, s in enumerate(seqs):
        out[i, : len(s)] = s
        lens[i] = len(s)
    return out, lens


def eval_cloze(forward_fn, params, cfg: ModelConfig, examples: List[dict], bsz: int = 64):
    """Final-word prediction: returns (accuracy, perplexity-of-gold)."""
    fwd = jax.jit(lambda p, t: forward_fn(p, cfg, t))
    correct, nll, n = 0, 0.0, 0
    for i in range(0, len(examples), bsz):
        chunk = examples[i : i + bsz]
        toks, lens = _pad_batch([e["ctx"] for e in chunk])
        logits = np.asarray(fwd(params, toks))
        for j, e in enumerate(chunk):
            lg = logits[j, lens[j] - 1]
            lp = lg - _logsumexp(lg)
            correct += int(np.argmax(lg) == e["gold"])
            nll += -float(lp[e["gold"]])
            n += 1
    return correct / n, math.exp(nll / n)


def eval_choice(forward_fn, params, cfg: ModelConfig, examples: List[dict], bsz: int = 64):
    """Multiple-choice by total log-prob of the continuation."""
    fwd = jax.jit(lambda p, t: forward_fn(p, cfg, t))
    flat: List[List[int]] = []
    spans: List[Tuple[int, int]] = []  # (ctx_len, total_len)
    for e in examples:
        for c in e["choices"]:
            flat.append(e["ctx"] + c)
            spans.append((len(e["ctx"]), len(e["ctx"]) + len(c)))
    scores = np.zeros(len(flat))
    for i in range(0, len(flat), bsz):
        toks, lens = _pad_batch(flat[i : i + bsz])
        logits = np.asarray(fwd(params, toks))
        for j in range(len(toks)):
            cl, tl = spans[i + j]
            for pos in range(cl - 1, tl - 1):
                lg = logits[j, pos]
                lp = lg - _logsumexp(lg)
                scores[i + j] += lp[toks[j, pos + 1]]
    correct, k = 0, 0
    for e in examples:
        nc = len(e["choices"])
        pred = int(np.argmax(scores[k : k + nc]))
        correct += int(pred == e["label"])
        k += nc
    return correct / len(examples)


def eval_tasks(forward_fn, params, cfg: ModelConfig, tasks: Dict[str, List[dict]]):
    out = {}
    for name, examples in tasks.items():
        if "choices" in examples[0]:
            out[name] = {"acc": eval_choice(forward_fn, params, cfg, examples)}
        else:
            acc, ppl = eval_cloze(forward_fn, params, cfg, examples)
            out[name] = {"acc": acc, "ppl": ppl}
    return out


def _logsumexp(x: np.ndarray) -> float:
    m = x.max()
    return m + math.log(np.exp(x - m).sum())
