"""Synthetic corpus + benchmark tasks (the Pile / lambada substitution).

The paper trains on the Pile (200B tokens) and evaluates on lambada,
hellaswag, winogrande, piqa, siqa, arc, openbookqa.  We have neither the
dataset nor the compute, so we substitute a *deterministic generative
grammar* with the statistical properties the paper's techniques rely on:

* **Zipfian token usage** — a long-tail unigram distribution over ~1K words;
  this is what makes the embedding LRU cache (§3.3) effective.
* **Squared-ReLU-driven activation sparsity** — any natural-ish language
  model exhibits it; we verify empirically (Figure 3 reproduction) that our
  trained models show the same layer-wise sparsity profile shape.
* **Long-range dependencies** — documents introduce named entities early and
  reference them in the final sentence, enabling a lambada-style cloze task
  (predict the final word; answer appears in the distant context only).

Tasks generated (Table 5 analogs):
  lambada_syn   — final-word cloze over long context (lambada_openai analog)
  lambada_hard  — same but with distractor entities (lambada_standard analog)
  cloze_syn     — choose the most plausible continuation (hellaswag analog)
  agree_syn     — subject/verb number agreement (winogrande-ish, syntax)
  assoc_syn     — object/place affinity (piqa analog, world knowledge)
  social_syn    — entity interaction outcomes (siqa analog)
  recall_syn    — recall an attribute stated earlier (arc/openbookqa analog)

Everything is seeded and reproducible; the vocabulary is fixed by
construction so the tokenizer needs no data pass.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from ..common import VOCAB_SIZE, rng

# ---------------------------------------------------------------------------
# Vocabulary
# ---------------------------------------------------------------------------

PAD, UNK, BOS, EOS = 0, 1, 2, 3
SPECIALS = ["<pad>", "<unk>", "<bos>", "<eos>"]

_CONSONANTS = "b c d f g h j k l m n p r s t v w z".split()
_VOWELS = "a e i o u".split()


def _coin_words(g: np.random.Generator, n: int, syllables: int) -> List[str]:
    """Pronounceable pseudo-words; deterministic, collision-free."""
    seen, out = set(), []
    while len(out) < n:
        w = "".join(
            g.choice(_CONSONANTS) + g.choice(_VOWELS)
            for _ in range(syllables)
        )
        if w not in seen:
            seen.add(w)
            out.append(w)
    return out


@dataclasses.dataclass
class Vocab:
    words: List[str]
    index: Dict[str, int]

    def encode(self, toks: Sequence[str]) -> List[int]:
        return [self.index.get(t, UNK) for t in toks]

    def decode(self, ids: Sequence[int]) -> List[str]:
        return [self.words[i] if 0 <= i < len(self.words) else "<unk>" for i in ids]

    def __len__(self) -> int:
        return len(self.words)


# Word-class sizes; total must stay <= VOCAB_SIZE.
N_NAMES = 48
N_OBJECTS = 288
N_PLACES = 160
N_VERBS = 96
N_ADJ = 144
FUNCTION_WORDS = (
    "the a an in on at to of and then but with was were is are had has "
    "who that it they he she this his her its near from into under over "
    "end finally later soon when after before because said gave took found "
    "lost saw met left kept brought carried wanted belonged returned ."
).split()


def build_vocab(seed: int = 7) -> Tuple[Vocab, Dict[str, List[str]]]:
    g = rng(seed)
    names = _coin_words(g, N_NAMES, 2)
    objects = _coin_words(g, N_OBJECTS, 3)
    places = _coin_words(g, N_PLACES, 3)
    verbs = _coin_words(g, N_VERBS, 2)
    adjs = _coin_words(g, N_ADJ, 2)
    # De-duplicate across classes (coin_words only dedups within a class).
    classes = {}
    seen = set(FUNCTION_WORDS) | set(SPECIALS)
    for cname, lst in [
        ("name", names),
        ("object", objects),
        ("place", places),
        ("verb", verbs),
        ("adj", adjs),
    ]:
        uniq = []
        for w in lst:
            if w in seen:
                w = w + "x"
            if w in seen:
                continue
            seen.add(w)
            uniq.append(w)
        classes[cname] = uniq

    words = list(SPECIALS) + FUNCTION_WORDS
    for cname in ("name", "object", "place", "verb", "adj"):
        words.extend(classes[cname])
    assert len(words) <= VOCAB_SIZE, f"vocab overflow: {len(words)}"
    # Pad the vocabulary to exactly VOCAB_SIZE with reserved (never-sampled)
    # tokens; these exercise the long-tail branch of the embedding cache.
    i = 0
    while len(words) < VOCAB_SIZE:
        words.append(f"<rsv{i}>")
        i += 1
    index = {w: i for i, w in enumerate(words)}
    return Vocab(words=words, index=index), classes


def zipf_weights(n: int, s: float = 1.15) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** s
    return w / w.sum()


# ---------------------------------------------------------------------------
# Document generator
# ---------------------------------------------------------------------------


class Grammar:
    """Probabilistic story grammar with persistent entity state.

    Each document tracks who-holds-what / who-is-where so that the closing
    sentence is *predictable from the long context* — the property the
    lambada benchmark tests.
    """

    def __init__(self, vocab: Vocab, classes: Dict[str, List[str]], seed: int):
        self.vocab = vocab
        self.classes = classes
        self.g = rng(seed)
        self.p = {k: zipf_weights(len(v)) for k, v in classes.items()}
        # Fixed latent affinities (world knowledge for assoc/social tasks):
        ga = rng(seed ^ 0xA5A5)
        self.obj_place = {
            o: classes["place"][int(ga.integers(len(classes["place"])))]
            for o in classes["object"]
        }
        self.verb_out = {
            v: ("gave" if ga.random() < 0.5 else "kept")
            for v in classes["verb"]
        }

    def pick(self, cls: str) -> str:
        c = self.classes[cls]
        return c[int(self.g.choice(len(c), p=self.p[cls]))]

    def pick2(self, cls: str) -> Tuple[str, str]:
        a = self.pick(cls)
        b = self.pick(cls)
        while b == a:
            b = self.pick(cls)
        return a, b

    def document(self) -> List[str]:
        """One story; returns tokens (words)."""
        g = self.g
        n1, n2 = self.pick2("name")
        obj = self.pick("object")
        adj = self.pick("adj")
        place = self.obj_place[obj]  # learnable obj->place affinity
        verb = self.pick("verb")
        toks: List[str] = ["<bos>"]
        toks += [n1, "took", "the", adj, obj, "to", "the", place, "."]
        n_mid = int(g.integers(1, 5))
        holder = n1
        for _ in range(n_mid):
            r = g.random()
            if r < 0.3:
                toks += ["at", "the", place, ",", n1, "met", n2, "."] if g.random() < 0.5 else [
                    n2, "was", "near", "the", place, "."
                ]
            elif r < 0.6:
                v2 = self.pick("verb")
                toks += [holder, v2, "the", obj, "with", n2, "."]
                if self.verb_out[v2] == "gave":
                    holder = n2
            elif r < 0.8:
                a2 = self.pick("adj")
                toks += ["the", obj, "was", a2, "and", adj, "."]
            else:
                o2 = self.pick("object")
                toks += [n2, "found", "a", o2, "at", "the", self.obj_place[o2], "."]
        # Closing sentence: the lambada-style long-range target.
        style = g.random()
        if style < 0.5:
            toks += ["in", "the", "end", "the", obj, "belonged", "to", holder, "."]
        elif style < 0.8:
            toks += ["finally", holder, "left", "the", place, "with", "the", obj, "."]
        else:
            toks += ["later", holder, "returned", "to", "the", place, "."]
        toks += ["<eos>"]
        # ',' is not in vocab; replace with 'and' (keeps everything in-vocab)
        return [("and" if t == "," else t) for t in toks]

    # ------------------------------------------------------------------
    # Benchmark task emitters.  Each returns (context_tokens, answers)
    # where answers is either a single gold continuation word (cloze) or
    # (choices, label) for multiple-choice scoring.
    # ------------------------------------------------------------------

    def task_lambada(self, hard: bool) -> Tuple[List[str], str]:
        doc = self.document()
        # find final-sentence holder token: last name occurrence
        name_set = set(self.classes["name"])
        idx = max(i for i, t in enumerate(doc) if t in name_set)
        ctx, gold = doc[:idx], doc[idx]
        if hard:
            # splice in a distractor sentence mentioning another name
            d1, d2 = self.pick2("name")
            distractor = [d1, "saw", d2, "near", "the", self.pick("place"), "."]
            cut = len(ctx) // 2
            ctx = ctx[:cut] + distractor + ctx[cut:]
        return ctx, gold

    def task_cloze(self) -> Tuple[List[str], List[List[str]], int]:
        doc = self.document()
        # choices: true final clause vs shuffled-object impostors
        name_set = set(self.classes["name"])
        idx = max(i for i, t in enumerate(doc) if t in name_set)
        ctx = doc[: idx - 2]  # cut before "to <holder>" / "with the <obj>"
        gold = doc[idx - 2 : idx + 1]
        choices = [gold]
        used = {gold[-1]}
        while len(choices) < 4:
            alt = list(gold)
            alt[-1] = self.pick("name")
            if alt[-1] in used:
                continue
            used.add(alt[-1])
            choices.append(alt)
        order = list(self.g.permutation(4))
        label = order.index(0)
        return ctx, [choices[i] for i in order], label

    def task_agree(self) -> Tuple[List[str], List[List[str]], int]:
        obj = self.pick("object")
        plural = self.g.random() < 0.5
        subj = ["the", obj + ("s" if plural else "")]
        # plural nouns are OOV -> approximate with "they"/"it" agreement:
        subj = ["they"] if plural else ["it"]
        ctx = subj
        choices = [["were", "lost", "."], ["was", "lost", "."]]
        label = 0 if plural else 1
        return ctx, choices, label

    def task_assoc(self) -> Tuple[List[str], List[List[str]], int]:
        obj = self.pick("object")
        ctx = ["the", obj, "was", "at", "the"]
        gold_place = self.obj_place[obj]
        alt = self.pick("place")
        while alt == gold_place:
            alt = self.pick("place")
        choices = [[gold_place, "."], [alt, "."]]
        order = list(self.g.permutation(2))
        return ctx, [choices[i] for i in order], order.index(0)

    def task_social(self) -> Tuple[List[str], List[List[str]], int]:
        n1, n2 = self.pick2("name")
        v = self.pick("verb")
        obj = self.pick("object")
        ctx = [n1, v, "the", obj, "with", n2, "and", "then", "the", obj, "belonged", "to"]
        gold = n2 if self.verb_out[v] == "gave" else n1
        other = n1 if gold == n2 else n2
        choices = [[gold, "."], [other, "."]]
        order = list(self.g.permutation(2))
        return ctx, [choices[i] for i in order], order.index(0)

    def task_recall(self) -> Tuple[List[str], str]:
        n1 = self.pick("name")
        adj = self.pick("adj")
        obj = self.pick("object")
        filler = []
        for _ in range(int(self.g.integers(1, 4))):
            filler += [self.pick("name"), "was", "near", "the", self.pick("place"), "."]
        ctx = [n1, "took", "the", adj, obj, "to", "the", self.obj_place[obj], "."] + filler + [
            "the",
            obj,
            "was",
        ]
        return ctx, adj


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def training_tokens(vocab: Vocab, classes: Dict[str, List[str]], n_tokens: int, seed: int = 11) -> np.ndarray:
    """A flat stream of token ids for LM training."""
    gram = Grammar(vocab, classes, seed)
    ids: List[int] = []
    while len(ids) < n_tokens:
        ids.extend(vocab.encode(gram.document()))
    return np.asarray(ids[:n_tokens], dtype=np.int32)


def make_tasks(vocab: Vocab, classes: Dict[str, List[str]], n_per_task: int = 200, seed: int = 1234) -> Dict[str, List[dict]]:
    """Benchmark suites encoded as token ids (held-out seed)."""
    gram = Grammar(vocab, classes, seed)
    tasks: Dict[str, List[dict]] = {k: [] for k in (
        "lambada_syn", "lambada_hard", "cloze_syn", "agree_syn",
        "assoc_syn", "social_syn", "recall_syn",
    )}
    for _ in range(n_per_task):
        ctx, gold = gram.task_lambada(hard=False)
        tasks["lambada_syn"].append(dict(ctx=vocab.encode(ctx), gold=vocab.index[gold]))
        ctx, gold = gram.task_lambada(hard=True)
        tasks["lambada_hard"].append(dict(ctx=vocab.encode(ctx), gold=vocab.index[gold]))
        ctx, choices, label = gram.task_cloze()
        tasks["cloze_syn"].append(dict(ctx=vocab.encode(ctx), choices=[vocab.encode(c) for c in choices], label=label))
        ctx, choices, label = gram.task_agree()
        tasks["agree_syn"].append(dict(ctx=vocab.encode(ctx), choices=[vocab.encode(c) for c in choices], label=label))
        ctx, choices, label = gram.task_assoc()
        tasks["assoc_syn"].append(dict(ctx=vocab.encode(ctx), choices=[vocab.encode(c) for c in choices], label=label))
        ctx, choices, label = gram.task_social()
        tasks["social_syn"].append(dict(ctx=vocab.encode(ctx), choices=[vocab.encode(c) for c in choices], label=label))
        ctx, gold = gram.task_recall()
        tasks["recall_syn"].append(dict(ctx=vocab.encode(ctx), gold=vocab.index[gold]))
    return tasks
