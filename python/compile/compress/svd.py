"""§3.1 — SVD decomposition of RWKV projection matrices.

`decompose(w, rank)` solves the truncated SVD and returns (L, R) with
L = U·Σ (tall) and R = Vᵀ (flat), exactly the paper's Eq. 1 mapping.
`decompose_model` rewrites a vanilla parameter pytree into the RWKV-Lite
structure (W_{r,k,v,g} in time-mix + W_r in channel-mix; W_o untouched —
the paper found decomposing W_o detrimental).  The result is then
continually pretrained (train.train_lm) to recover capacity.
"""

from __future__ import annotations

import copy
from typing import Any, Dict, Tuple

import numpy as np

from ..common import ModelConfig

DECOMPOSED_ATT = ("wr", "wk", "wv", "wg")  # not wo
DECOMPOSED_FFN = ("wr",)


def decompose(w: np.ndarray, rank: int) -> Tuple[np.ndarray, np.ndarray]:
    """Truncated SVD: W (M, N) ≈ L (M, rank) @ R (rank, N)."""
    u, s, vt = np.linalg.svd(np.asarray(w, np.float64), full_matrices=False)
    l = (u[:, :rank] * s[:rank]).astype(np.float32)
    r = vt[:rank, :].astype(np.float32)
    return l, r


def reconstruction_error(w: np.ndarray, l: np.ndarray, r: np.ndarray) -> float:
    """Relative Frobenius error of the rank-r approximation."""
    diff = np.linalg.norm(w - l @ r)
    return float(diff / (np.linalg.norm(w) + 1e-12))


def decompose_model(params: Dict[str, Any], cfg: ModelConfig) -> Dict[str, Any]:
    """Vanilla params -> simple-SVD params (paper's RWKV-ours init)."""
    assert cfg.svd_rank_div > 0 and not cfg.enhanced_svd
    rank = cfg.svd_rank
    out = copy.deepcopy(params)
    for block in out["blocks"]:
        for key in DECOMPOSED_ATT:
            w = block["att"][key]["w"]
            l, r = decompose(w, rank)
            block["att"][key] = {"l": l, "r": r}
        for key in DECOMPOSED_FFN:
            w = block["ffn"][key]["w"]
            l, r = decompose(w, rank)
            block["ffn"][key] = {"l": l, "r": r}
    return out


def decomposition_report(params: Dict[str, Any], cfg: ModelConfig) -> Dict[str, float]:
    """Per-matrix relative error at the configured rank (sanity/telemetry)."""
    rank = cfg.svd_rank if cfg.svd_rank_div else cfg.dim // 8
    report = {}
    for i, block in enumerate(params["blocks"]):
        for scope, keys in (("att", DECOMPOSED_ATT), ("ffn", DECOMPOSED_FFN)):
            for key in keys:
                p = block[scope][key]
                if "w" not in p:
                    continue
                l, r = decompose(p["w"], rank)
                report[f"blocks.{i}.{scope}.{key}"] = reconstruction_error(p["w"], l, r)
    return report
