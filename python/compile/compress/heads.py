"""§3.3 — Embedding clustering + hierarchical-head training.

1. K-means (implemented here, substrate S9 — no sklearn in this image) on
   the trained token embeddings -> N clusters.
2. Cluster head H1 (D, N) trained with KL(H̄ ‖ H1) where H̄ sums the
   original head's token probabilities per cluster (paper Eq. 6).
   Training data = hidden states sampled by running the frozen model over
   the corpus (~1B tokens in the paper; scaled here).
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import ModelConfig, rng
from ..models import rwkv

N_CLUSTERS = 32  # scaled from the paper's 200-of-65536 (we have 1024 tokens)


# ---------------------------------------------------------------------------
# K-means
# ---------------------------------------------------------------------------


def kmeans(x: np.ndarray, k: int, iters: int = 30, seed: int = 3) -> Tuple[np.ndarray, np.ndarray]:
    """Lloyd's algorithm with k-means++ seeding. Returns (centroids, assign)."""
    g = rng(seed)
    n = x.shape[0]
    # k-means++ init
    centers = [x[int(g.integers(n))]]
    d2 = np.full(n, np.inf)
    for _ in range(1, k):
        d2 = np.minimum(d2, ((x - centers[-1]) ** 2).sum(1))
        probs = d2 / d2.sum()
        centers.append(x[int(g.choice(n, p=probs))])
    c = np.stack(centers)
    assign = np.zeros(n, np.int32)
    for _ in range(iters):
        d = ((x[:, None, :] - c[None, :, :]) ** 2).sum(-1)
        new_assign = d.argmin(1).astype(np.int32)
        if (new_assign == assign).all():
            break
        assign = new_assign
        for j in range(k):
            m = assign == j
            if m.any():
                c[j] = x[m].mean(0)
            else:  # re-seed an empty cluster at the farthest point
                c[j] = x[d.min(1).argmax()]
    return c, assign


def cluster_embeddings(params: Dict[str, Any], k: int = N_CLUSTERS, seed: int = 3):
    emb = np.asarray(params["emb"])
    return kmeans(emb, k, seed=seed)


# ---------------------------------------------------------------------------
# Hidden-state sampling + H1 training
# ---------------------------------------------------------------------------


def sample_hiddens(
    params: Dict[str, Any], cfg: ModelConfig, tokens: np.ndarray, n_samples: int = 4000, seqlen: int = 64
) -> np.ndarray:
    """Final-LN hidden states from the frozen model over corpus slices."""
    n_seq = max(1, n_samples // seqlen)
    g = rng(77)
    starts = g.integers(0, len(tokens) - seqlen - 1, size=n_seq)
    batch = np.stack([tokens[s : s + seqlen] for s in starts]).astype(np.int32)

    @jax.jit
    def run(params, toks):
        x = params["emb"][toks]
        x = rwkv._ln(x, params["ln0"])
        for block in params["blocks"]:
            x = x + rwkv._time_mix_seq(rwkv._ln(x, block["ln1"]), block["att"], cfg)
            x = x + rwkv._chan_mix_seq(rwkv._ln(x, block["ln2"]), block["ffn"], cfg)
        return rwkv._ln(x, params["ln_out"])

    h = np.asarray(run(params, batch)).reshape(-1, cfg.dim)
    return h[:n_samples]


def train_cluster_head(
    params: Dict[str, Any],
    cfg: ModelConfig,
    assign: np.ndarray,
    hiddens: np.ndarray,
    epochs: int = 30,
    bsz: int = 256,
    lr: float = 2e-3,
    seed: int = 21,
    verbose: bool = True,
) -> np.ndarray:
    """Train H1 (D, N) with KL(H̄ ‖ softmax(x @ H1)) (paper Eq. 6)."""
    from ..train import adamw_init, adamw_update

    n_clusters = int(assign.max()) + 1
    g = rng(seed)
    h1 = (g.standard_normal((cfg.dim, n_clusters)) / np.sqrt(cfg.dim)).astype(np.float32)
    head = jnp.asarray(params["head"])
    assign_j = jnp.asarray(assign)
    hid = jnp.asarray(hiddens)

    # Aggregation matrix A (V, N): A[v, c] = 1 if token v is in cluster c.
    agg = jnp.zeros((head.shape[1], n_clusters), jnp.float32).at[
        jnp.arange(len(assign)), assign_j
    ].set(1.0)

    opt = adamw_init(h1)

    @jax.jit
    def update(h1, opt, idx):
        def loss_fn(h1):
            x = hid[idx]
            p_tok = jax.nn.softmax(x @ head, axis=-1)
            p_bar = p_tok @ agg  # H̄: summed token probs per cluster
            logq = jax.nn.log_softmax(x @ h1, axis=-1)
            kl = jnp.sum(p_bar * (jnp.log(p_bar + 1e-9) - logq), axis=-1)
            return kl.mean()

        loss, grads = jax.value_and_grad(loss_fn)(h1)
        h1, opt = adamw_update(h1, grads, opt, lr, wd=0.0)
        return h1, opt, loss

    n = hiddens.shape[0]
    h1 = jnp.asarray(h1)
    for ep in range(epochs):
        perm = g.permutation(n)
        for s in range(max(1, n // bsz)):
            idx = jnp.asarray(perm[s * bsz : (s + 1) * bsz])
            h1, opt, loss = update(h1, opt, idx)
        if verbose and (ep % 10 == 0 or ep == epochs - 1):
            print(f"  [hh] epoch {ep:3d} KL {float(loss):.4f}", flush=True)
    return np.asarray(h1)


def head_coverage(
    params: Dict[str, Any], cfg: ModelConfig, h1: np.ndarray, assign: np.ndarray, hiddens: np.ndarray,
    p_min: float = 0.95, k_min: int = 3, k_max: int = 16,
) -> Dict[str, float]:
    """Telemetry: how often the selected clusters contain the argmax token."""
    head = np.asarray(params["head"])
    hit, loads = 0, []
    for x in hiddens[:512]:
        c = _softmax(x @ h1)
        order = np.argsort(-c)
        csum, sel = 0.0, []
        for ci in order:
            sel.append(ci)
            csum += c[ci]
            if (csum >= p_min and len(sel) >= k_min) or len(sel) >= k_max:
                break
        gold_cluster = assign[int(np.argmax(x @ head))]
        hit += int(gold_cluster in sel)
        loads.append(sum((assign == ci).sum() for ci in sel))
    return {"argmax_coverage": hit / 512, "mean_tokens_loaded": float(np.mean(loads))}


def _softmax(x: np.ndarray) -> np.ndarray:
    e = np.exp(x - x.max())
    return e / e.sum()
