"""§3.2 — FFN sparsity: activation collection + predictor training.

Pipeline (mirrors the paper's §4 "How are sparsity predictors trained"):
  1. Run the frozen model over ~5000 corpus tokens, recording for every
     layer the channel-mix FFN pre-activation input x and the ground-truth
     activation mask  relu(x @ W_k) > 0.
  2. Train one MLP predictor per layer (L1: D->N, L2: N->F, sigmoid), BCE
     against the ground-truth mask.  All layers train jointly as one jit
     (independent losses summed).
  3. Build the 1-bit shadow predictor: sign-quantized W_k + per-column
     scale; score = x @ W^{INT1}, active = score above the t-th percentile.
  4. The runtime ensemble is max(P_MLP, P_quant) — union of the masks
     (rust/src/engine/sparse_ffn.rs).  Here we also compute recall /
     precision / sparsity stats for Figure 9.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import ModelConfig, rng
from ..models import rwkv
from . import quant

# N = D/4: the paper stresses (§2.2) that predictor overhead must stay
# negligible for small models — at our scaled dims a D/2 hidden layer was
# ~30% of the compressed model, swamping the §3.2 savings.
PRED_HIDDEN_DIV = 4


# ---------------------------------------------------------------------------
# 1. Activation collection
# ---------------------------------------------------------------------------


def collect_activations(
    params: Dict[str, Any], cfg: ModelConfig, tokens: np.ndarray, n_samples: int = 5000, seqlen: int = 64
) -> List[Dict[str, np.ndarray]]:
    """Returns per-layer {"x": (N, D) ffn inputs, "mask": (N, F) bool}."""
    n_seq = max(1, n_samples // seqlen)
    g = rng(123)
    starts = g.integers(0, len(tokens) - seqlen - 1, size=n_seq)
    batch = np.stack([tokens[s : s + seqlen] for s in starts]).astype(np.int32)

    captured: List[Dict[str, np.ndarray]] = [dict() for _ in range(cfg.layers)]

    @jax.jit
    def run(params, toks):
        x = params["emb"][toks]
        x = rwkv._ln(x, params["ln0"])
        per_layer = []
        for block in params["blocks"]:
            x = x + rwkv._time_mix_seq(rwkv._ln(x, block["ln1"]), block["att"], cfg)
            xf = rwkv._ln(x, block["ln2"])
            sx = rwkv._shift(xf)
            xk = rwkv._lerp(xf, sx, block["ffn"]["mu_k"])
            h = jnp.maximum(xk @ block["ffn"]["wk"], 0.0)
            per_layer.append((xk, h > 0))
            xr = rwkv._lerp(xf, sx, block["ffn"]["mu_r"])
            from .. import kernels

            kns = kernels.get("jnp")
            r = jax.nn.sigmoid(rwkv._proj(xr, block["ffn"]["wr"], kns))
            x = x + r * ((h * h) @ block["ffn"]["wv"])
        return per_layer

    outs = run(params, batch)
    for i, (xk, mask) in enumerate(outs):
        captured[i]["x"] = np.asarray(xk).reshape(-1, cfg.dim)[:n_samples]
        captured[i]["mask"] = np.asarray(mask).reshape(-1, cfg.ffn_dim)[:n_samples]
    return captured


def sparsity_profile(activations: List[Dict[str, np.ndarray]]) -> List[float]:
    """Figure 3: fraction of zero activations per layer."""
    return [float(1.0 - a["mask"].mean()) for a in activations]


# ---------------------------------------------------------------------------
# 2. MLP predictors (all layers jointly)
# ---------------------------------------------------------------------------


def init_predictors(cfg: ModelConfig, seed: int = 5) -> List[Dict[str, np.ndarray]]:
    g = rng(seed)
    n = cfg.dim // PRED_HIDDEN_DIV
    preds = []
    for _ in range(cfg.layers):
        preds.append(
            {
                "l1": (g.standard_normal((cfg.dim, n)) / np.sqrt(cfg.dim)).astype(np.float32),
                "l2": (g.standard_normal((n, cfg.ffn_dim)) / np.sqrt(n)).astype(np.float32),
            }
        )
    return preds


def predictor_logits(pred: Dict[str, Any], x) -> jnp.ndarray:
    """sigma-input logits of the MLP predictor (Eq. 3 before thresholding)."""
    return jnp.maximum(x @ pred["l1"], 0.0) @ pred["l2"]


def train_predictors(
    preds: List[Dict[str, np.ndarray]],
    activations: List[Dict[str, np.ndarray]],
    epochs: int = 50,
    bsz: int = 512,
    lr: float = 1e-3,
    seed: int = 9,
    verbose: bool = True,
) -> List[Dict[str, np.ndarray]]:
    """Joint BCE training of all per-layer MLP predictors."""
    from ..train import adamw_init, adamw_update

    xs = jnp.stack([jnp.asarray(a["x"]) for a in activations])  # (L, N, D)
    ys = jnp.stack([jnp.asarray(a["mask"], jnp.float32) for a in activations])

    params = preds
    opt = adamw_init(params)

    @jax.jit
    def update(params, opt, idx):
        def loss_fn(ps):
            total = 0.0
            for li, p in enumerate(ps):
                xb = xs[li, idx]
                yb = ys[li, idx]
                lg = predictor_logits(p, xb)
                # numerically-stable BCE-with-logits; positive class (active
                # neuron) upweighted: a false negative kills accuracy, a
                # false positive only costs memory (paper §2.2 challenge 1).
                pos_w = 2.0
                loss = jnp.mean(
                    pos_w * yb * jax.nn.softplus(-lg) + (1 - yb) * jax.nn.softplus(lg)
                )
                total = total + loss
            return total / len(ps)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(params, grads, opt, lr, wd=0.0)
        return params, opt, loss

    n = xs.shape[1]
    g = rng(seed)
    steps_per_epoch = max(1, n // bsz)
    for ep in range(epochs):
        perm = g.permutation(n)
        for s in range(steps_per_epoch):
            idx = jnp.asarray(perm[s * bsz : (s + 1) * bsz])
            params, opt, loss = update(params, opt, idx)
        if verbose and (ep % 10 == 0 or ep == epochs - 1):
            print(f"  [pred] epoch {ep:3d} loss {float(loss):.4f}", flush=True)
    return [
        {"l1": np.asarray(p["l1"]), "l2": np.asarray(p["l2"])} for p in params
    ]


# ---------------------------------------------------------------------------
# 3. Quantized shadow predictors + 4. ensemble statistics
# ---------------------------------------------------------------------------


def build_shadow(params: Dict[str, Any], bits: int = 1) -> List[Dict[str, np.ndarray]]:
    """Per-layer quantized W_k shadow (1-bit packed, 4-bit nibble-packed,
    or n-bit int8 for analysis)."""
    out = []
    for block in params["blocks"]:
        wk = np.asarray(block["ffn"]["wk"])
        if bits == 1:
            packed, scale = quant.sign_quant(wk)
            out.append({"wq_packed": packed, "wq_scale": scale})
        elif bits == 4:
            packed, scale = quant.nibble_quant(wk)
            out.append({"wq4_packed": packed, "wq4_scale": scale})
        else:
            q, scale = quant.int_quant(wk, bits)
            out.append({"wq": q, "wq_scale": scale})
    return out


def shadow_scores(shadow: Dict[str, np.ndarray], x: np.ndarray, rows: int) -> np.ndarray:
    if "wq_packed" in shadow:
        w = quant.sign_dequant(shadow["wq_packed"], shadow["wq_scale"], rows)
    elif "wq4_packed" in shadow:
        w = quant.nibble_dequant(shadow["wq4_packed"], shadow["wq4_scale"], rows)
    else:
        w = quant.int_dequant(shadow["wq"], shadow["wq_scale"])
    return x @ w


def ensemble_stats(
    params: Dict[str, Any],
    cfg: ModelConfig,
    preds: List[Dict[str, np.ndarray]],
    shadows: List[Dict[str, np.ndarray]],
    activations: List[Dict[str, np.ndarray]],
    t_mlp: float = 0.7,
    t_quant: float = 0.8,
) -> Dict[str, Any]:
    """Recall / precision / kept-fraction per layer for MLP, quant, ensemble.

    `t_mlp` thresholds the sigmoid; `t_quant` is the keep-percentile of the
    shadow scores (paper §5.1 uses 0.7 / 0.8).
    """
    per_layer = []
    for li in range(cfg.layers):
        x = activations[li]["x"]
        gt = activations[li]["mask"]
        mlp_p = jax.nn.sigmoid(predictor_logits(preds[li], jnp.asarray(x)))
        m_mlp = np.asarray(mlp_p) >= t_mlp
        sc = shadow_scores(shadows[li], x, cfg.dim)
        thr = np.quantile(sc, t_quant, axis=1, keepdims=True)
        m_q = sc >= thr
        m_ens = m_mlp | m_q

        def stats(m):
            tp = float((m & gt).sum())
            recall = tp / max(1.0, float(gt.sum()))
            precision = tp / max(1.0, float(m.sum()))
            kept = float(m.mean())
            return {"recall": recall, "precision": precision, "kept": kept}

        per_layer.append({"mlp": stats(m_mlp), "quant": stats(m_q), "ensemble": stats(m_ens), "gt_kept": float(gt.mean())})
    return {"per_layer": per_layer}
