"""Quantizers (§B.6 INT8 path + §3.2 deeply-quantized predictors).

All quantization here is per-output-column symmetric (matvec is x @ W with
W (in, out); each output column gets one scale), matching the rust fused
dequant kernels (rust/src/tensor/int8.rs) bit-for-bit:

    w_q[i, j] = clip(round(w[i, j] / scale[j]), -qmax, qmax)
    scale[j]  = max_i |w[i, j]| / qmax

`sign_quant` is the 1-bit case used by the sparsity shadow predictor
(Eq. 4): weights become {-1, +1} packed 8-per-byte, one f32 scale per
column (the mean |w| of that column).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def int_quant(w: np.ndarray, bits: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-column quantization to `bits` (stored in int8)."""
    assert 2 <= bits <= 8
    qmax = (1 << (bits - 1)) - 1
    scale = np.abs(w).max(axis=0) / qmax
    scale = np.where(scale == 0, 1.0, scale).astype(np.float32)
    q = np.clip(np.round(w / scale), -qmax, qmax).astype(np.int8)
    return q, scale


def int_dequant(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale


def sign_quant(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """1-bit: sign matrix packed row-major LSB-first + per-column mean-|w| scale."""
    scale = np.abs(w).mean(axis=0).astype(np.float32)
    signs = (w >= 0).astype(np.uint8)  # 1 -> +1, 0 -> -1
    packed = np.packbits(signs, axis=0, bitorder="little")
    return packed, scale


def sign_dequant(packed: np.ndarray, scale: np.ndarray, rows: int) -> np.ndarray:
    bits = np.unpackbits(packed, axis=0, count=rows, bitorder="little")
    return (bits.astype(np.float32) * 2.0 - 1.0) * scale


def nibble_quant(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """4-bit: symmetric per-column quant packed two-rows-per-byte.

    Row 2i sits in the LOW nibble and row 2i+1 in the HIGH nibble of byte
    (i, j); each nibble stores q+8 with q in [-7, 7] (offset binary).
    Matches rust `tensor::nib4_matvec`.
    """
    q, scale = int_quant(w, 4)  # q in [-7, 7]
    qu = (q.astype(np.int16) + 8).astype(np.uint8)
    if qu.shape[0] % 2 == 1:
        qu = np.vstack([qu, np.full((1, qu.shape[1]), 8, np.uint8)])  # pad = 0
    packed = qu[0::2] | (qu[1::2] << 4)
    return packed.astype(np.uint8), scale


def nibble_dequant(packed: np.ndarray, scale: np.ndarray, rows: int) -> np.ndarray:
    lo = (packed & 0xF).astype(np.int16) - 8
    hi = (packed >> 4).astype(np.int16) - 8
    out = np.empty((packed.shape[0] * 2, packed.shape[1]), np.float32)
    out[0::2] = lo
    out[1::2] = hi
    return out[:rows] * scale


def quant_error(w: np.ndarray, bits: int) -> float:
    """Relative Frobenius error introduced by `bits`-bit quantization."""
    q, s = int_quant(w, bits)
    return float(np.linalg.norm(w - int_dequant(q, s)) / (np.linalg.norm(w) + 1e-12))


# ---------------------------------------------------------------------------
# Group-quantized streaming weights (Q4 / Q4_1) — rust/src/tensor/q4.rs
# ---------------------------------------------------------------------------

#: Elements per quantization group, along the row (col) axis.
Q4_GROUP = 32


def _pack_nibbles(nib: np.ndarray, cols: int) -> np.ndarray:
    """Pack a (rows, padded_cols) array of 4-bit values two-per-byte:
    even col -> LOW nibble, odd col -> HIGH nibble of byte (r, c // 2)."""
    packed = (nib[:, 0::2] | (nib[:, 1::2] << 4)).astype(np.uint8)
    return np.ascontiguousarray(packed[:, : (cols + 1) // 2])


def group_q4(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Q4: 32-element groups along cols, per-group symmetric f16 scale.

    Returns (packed (rows, ceil(cols/2)) u8, scale (rows, ceil(cols/32)) f16).
    Bit-exact with rust `tensor::q4::quantize_q4`: the quantizer divides by
    the f16-ROUNDED scale (so python and rust agree on every nibble), all
    arithmetic stays in float32, rounding is ties-to-even (np.round), and
    the pad nibble of an odd trailing column is 8 (offset-binary zero).
    """
    w = np.ascontiguousarray(w, np.float32)
    rows, cols = w.shape
    ng = -(-cols // Q4_GROUP)
    pcols = ng * Q4_GROUP
    wp = np.zeros((rows, pcols), np.float32)
    wp[:, :cols] = w
    g = wp.reshape(rows, ng, Q4_GROUP)
    amax = np.abs(g).max(axis=2)  # zero padding is inert under |.|max
    sbits = (amax / np.float32(7.0)).astype(np.float16)
    s = sbits.astype(np.float32)
    denom = np.where(s == 0.0, np.float32(1.0), s)
    q = np.clip(np.round(g / denom[:, :, None]), -7, 7).astype(np.int16) + 8
    nib = q.reshape(rows, pcols).astype(np.uint8)
    nib[:, cols:] = 8
    return _pack_nibbles(nib, cols), sbits


def group_q4_dequant(packed: np.ndarray, scale: np.ndarray, cols: int) -> np.ndarray:
    """Inverse of `group_q4` (float32), matching rust `dq4` per element."""
    rows = packed.shape[0]
    nib = np.empty((rows, packed.shape[1] * 2), np.int16)
    nib[:, 0::2] = (packed & 0xF).astype(np.int16) - 8
    nib[:, 1::2] = ((packed >> 4) & 0xF).astype(np.int16) - 8
    s = np.repeat(scale.astype(np.float32), Q4_GROUP, axis=1)
    return s[:, :cols] * nib[:, :cols].astype(np.float32)


def group_q4_1(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Q4_1: per-group affine — f16 scale (range/15) plus f16 min offset.

    Returns (packed, scale (rows, ng) f16, min (rows, ng) f16).  Bit-exact
    with rust `tensor::q4::quantize_q4_1`: min/max are taken over the REAL
    elements only (ragged groups padded with +/-inf, never zero), both
    parameters are f16-rounded before quantizing, and the pad nibble of an
    odd trailing column is 0.
    """
    w = np.ascontiguousarray(w, np.float32)
    rows, cols = w.shape
    ng = -(-cols // Q4_GROUP)
    pcols = ng * Q4_GROUP
    lo = np.full((rows, pcols), np.inf, np.float32)
    hi = np.full((rows, pcols), -np.inf, np.float32)
    lo[:, :cols] = w
    hi[:, :cols] = w
    mn = lo.reshape(rows, ng, Q4_GROUP).min(axis=2)
    mx = hi.reshape(rows, ng, Q4_GROUP).max(axis=2)
    sbits = ((mx - mn) / np.float32(15.0)).astype(np.float16)
    mbits = mn.astype(np.float16)
    s = sbits.astype(np.float32)
    m = mbits.astype(np.float32)
    denom = np.where(s == 0.0, np.float32(1.0), s)
    wp = np.zeros((rows, pcols), np.float32)
    wp[:, :cols] = w
    g = wp.reshape(rows, ng, Q4_GROUP)
    q = np.clip(np.round((g - m[:, :, None]) / denom[:, :, None]), 0, 15)
    nib = q.reshape(rows, pcols).astype(np.uint8)
    nib[:, cols:] = 0
    return _pack_nibbles(nib, cols), sbits, mbits


def group_q4_1_dequant(
    packed: np.ndarray, scale: np.ndarray, mn: np.ndarray, cols: int
) -> np.ndarray:
    """Inverse of `group_q4_1` (float32), matching rust `dq4_1`."""
    rows = packed.shape[0]
    nib = np.empty((rows, packed.shape[1] * 2), np.uint8)
    nib[:, 0::2] = packed & 0xF
    nib[:, 1::2] = (packed >> 4) & 0xF
    s = np.repeat(scale.astype(np.float32), Q4_GROUP, axis=1)[:, :cols]
    m = np.repeat(mn.astype(np.float32), Q4_GROUP, axis=1)[:, :cols]
    return s * nib[:, :cols].astype(np.float32) + m
