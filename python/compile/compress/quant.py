"""Quantizers (§B.6 INT8 path + §3.2 deeply-quantized predictors).

All quantization here is per-output-column symmetric (matvec is x @ W with
W (in, out); each output column gets one scale), matching the rust fused
dequant kernels (rust/src/tensor/int8.rs) bit-for-bit:

    w_q[i, j] = clip(round(w[i, j] / scale[j]), -qmax, qmax)
    scale[j]  = max_i |w[i, j]| / qmax

`sign_quant` is the 1-bit case used by the sparsity shadow predictor
(Eq. 4): weights become {-1, +1} packed 8-per-byte, one f32 scale per
column (the mean |w| of that column).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def int_quant(w: np.ndarray, bits: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-column quantization to `bits` (stored in int8)."""
    assert 2 <= bits <= 8
    qmax = (1 << (bits - 1)) - 1
    scale = np.abs(w).max(axis=0) / qmax
    scale = np.where(scale == 0, 1.0, scale).astype(np.float32)
    q = np.clip(np.round(w / scale), -qmax, qmax).astype(np.int8)
    return q, scale


def int_dequant(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale


def sign_quant(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """1-bit: sign matrix packed row-major LSB-first + per-column mean-|w| scale."""
    scale = np.abs(w).mean(axis=0).astype(np.float32)
    signs = (w >= 0).astype(np.uint8)  # 1 -> +1, 0 -> -1
    packed = np.packbits(signs, axis=0, bitorder="little")
    return packed, scale


def sign_dequant(packed: np.ndarray, scale: np.ndarray, rows: int) -> np.ndarray:
    bits = np.unpackbits(packed, axis=0, count=rows, bitorder="little")
    return (bits.astype(np.float32) * 2.0 - 1.0) * scale


def nibble_quant(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """4-bit: symmetric per-column quant packed two-rows-per-byte.

    Row 2i sits in the LOW nibble and row 2i+1 in the HIGH nibble of byte
    (i, j); each nibble stores q+8 with q in [-7, 7] (offset binary).
    Matches rust `tensor::nib4_matvec`.
    """
    q, scale = int_quant(w, 4)  # q in [-7, 7]
    qu = (q.astype(np.int16) + 8).astype(np.uint8)
    if qu.shape[0] % 2 == 1:
        qu = np.vstack([qu, np.full((1, qu.shape[1]), 8, np.uint8)])  # pad = 0
    packed = qu[0::2] | (qu[1::2] << 4)
    return packed.astype(np.uint8), scale


def nibble_dequant(packed: np.ndarray, scale: np.ndarray, rows: int) -> np.ndarray:
    lo = (packed & 0xF).astype(np.int16) - 8
    hi = (packed >> 4).astype(np.int16) - 8
    out = np.empty((packed.shape[0] * 2, packed.shape[1]), np.float32)
    out[0::2] = lo
    out[1::2] = hi
    return out[:rows] * scale


def quant_error(w: np.ndarray, bits: int) -> float:
    """Relative Frobenius error introduced by `bits`-bit quantization."""
    q, s = int_quant(w, bits)
    return float(np.linalg.norm(w - int_dequant(q, s)) / (np.linalg.norm(w) + 1e-12))
