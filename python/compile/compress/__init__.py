from . import heads, quant, sparsity, svd  # noqa: F401
