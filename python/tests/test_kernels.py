"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes (the session guide's core signal): every
kernel must match its ref within fp32 tolerance across random shapes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

P = kernels.get("pallas")

TOL = dict(rtol=2e-4, atol=2e-4)


def fa(g, *shape):
    return g.standard_normal(shape).astype(np.float32)


@settings(max_examples=12, deadline=None)
@given(
    h=st.sampled_from([1, 2, 4, 8]),
    s=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_wkv5_step_matches_ref(h, s, seed):
    g = np.random.default_rng(seed)
    r, k, v = fa(g, h, s), fa(g, h, s), fa(g, h, s)
    w = np.exp(-np.exp(fa(g, h, s)))
    u = fa(g, h, s)
    state = fa(g, h, s, s)
    o1, s1 = ref.wkv5_step(r, k, v, w, u, state)
    o2, s2 = P.wkv5_step(r, k, v, w, u, state)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), **TOL)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), **TOL)


@settings(max_examples=8, deadline=None)
@given(
    t=st.sampled_from([1, 3, 7, 16]),
    h=st.sampled_from([1, 4]),
    s=st.sampled_from([4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_wkv5_seq_matches_ref(t, h, s, seed):
    g = np.random.default_rng(seed)
    r, k, v = fa(g, t, h, s), fa(g, t, h, s), fa(g, t, h, s)
    w = np.exp(-np.exp(fa(g, h, s)))
    u = fa(g, h, s)
    state = fa(g, h, s, s)
    o1, s1 = ref.wkv5_seq(r, k, v, w, u, state)
    o2, s2 = P.wkv5_seq(r, k, v, w, u, state)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), **TOL)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), **TOL)


def test_wkv5_seq_equals_iterated_steps():
    g = np.random.default_rng(3)
    t, h, s = 5, 2, 8
    r, k, v = fa(g, t, h, s), fa(g, t, h, s), fa(g, t, h, s)
    w = np.exp(-np.exp(fa(g, h, s)))
    u = fa(g, h, s)
    state = fa(g, h, s, s)
    outs_seq, final_seq = ref.wkv5_seq(r, k, v, w, u, state)
    st_ = state
    for i in range(t):
        o, st_ = ref.wkv5_step(r[i], k[i], v[i], w, u, st_)
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs_seq[i]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(final_seq), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    d=st.sampled_from([8, 32, 64]),
    fmul=st.sampled_from([2, 4, 7]),
    masked=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_sqrelu_ffn_matches_ref(d, fmul, masked, seed):
    g = np.random.default_rng(seed)
    f = d * fmul // 2 * 2
    x = fa(g, d)
    wk, wv = fa(g, d, f), fa(g, f, d)
    mask = (g.random(f) < 0.4).astype(np.float32) if masked else None
    a = ref.sqrelu_ffn(x, wk, wv, mask)
    b = P.sqrelu_ffn(x, wk, wv, mask)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-2)


def test_ffn_mask_zeroes_neurons():
    g = np.random.default_rng(1)
    d, f = 16, 32
    x, wk, wv = fa(g, d), fa(g, d, f), fa(g, f, d)
    zero_mask = np.zeros(f, np.float32)
    out = np.asarray(ref.sqrelu_ffn(x, wk, wv, zero_mask))
    np.testing.assert_allclose(out, np.zeros(d), atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 32, 128]),
    kdiv=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lowrank_matches_ref(m, kdiv, seed):
    g = np.random.default_rng(seed)
    r = max(1, m // kdiv)
    x, l, rr = fa(g, m), fa(g, m, r), fa(g, r, m)
    np.testing.assert_allclose(
        np.asarray(ref.lowrank_proj(x, l, rr)), np.asarray(P.lowrank_proj(x, l, rr)), **TOL
    )
    d = fa(g, m)
    np.testing.assert_allclose(
        np.asarray(ref.enhanced_lowrank_proj(x, l, rr, d)),
        np.asarray(P.enhanced_lowrank_proj(x, l, rr, d)),
        rtol=1e-3,
        atol=1e-3,
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 64]),
    n=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_int8_matvec_matches_ref(m, n, seed):
    g = np.random.default_rng(seed)
    x = fa(g, m)
    wq = g.integers(-127, 128, (m, n)).astype(np.int8)
    scale = (g.random(n) + 0.05).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(ref.int8_matvec(x, wq, scale)),
        np.asarray(P.int8_matvec(x, wq, scale)),
        rtol=1e-3,
        atol=1e-2,
    )


def test_wkv_decay_shrinks_state():
    """Property: with k=v=0, state decays monotonically toward zero."""
    g = np.random.default_rng(5)
    h, s = 2, 8
    z = np.zeros((h, s), np.float32)
    w = np.full((h, s), 0.5, np.float32)
    u = z
    state = fa(g, h, s, s)
    norm0 = float(np.abs(state).sum())
    _, st1 = ref.wkv5_step(z, z, z, w, u, state)
    _, st2 = ref.wkv5_step(z, z, z, w, u, np.asarray(st1))
    assert float(np.abs(np.asarray(st1)).sum()) < norm0
    assert float(np.abs(np.asarray(st2)).sum()) < float(np.abs(np.asarray(st1)).sum())
