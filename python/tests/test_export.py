"""`.rkv` checkpoint format: round trip, alignment, naming contract."""

import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import export
from compile.common import ModelConfig
from compile.models import rwkv

TINY = ModelConfig(arch="rwkv", variant="tiny", dim=32, layers=2, vocab=64, head_size=8)


def test_round_trip_basic(tmp_path, rng):
    tensors = {
        "a": rng.standard_normal((4, 8)).astype(np.float32),
        "b": rng.standard_normal(16).astype(np.float16),
        "c": rng.integers(-100, 100, (3, 5)).astype(np.int8),
        "d": rng.integers(0, 255, 7).astype(np.uint8),
        "e": rng.integers(0, 10, 9).astype(np.int32),
    }
    path = str(tmp_path / "t.rkv")
    export.write_rkv(path, tensors)
    back = export.read_rkv(path)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


@settings(max_examples=10, deadline=None)
@given(
    n_tensors=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_round_trip_random_shapes(n_tensors, seed):
    g = np.random.default_rng(seed)
    tensors = {}
    for i in range(n_tensors):
        ndim = int(g.integers(1, 4))
        shape = tuple(int(g.integers(1, 9)) for _ in range(ndim))
        tensors[f"t{i}"] = g.standard_normal(shape).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.rkv")
        export.write_rkv(path, tensors)
        back = export.read_rkv(path)
        for k, v in tensors.items():
            np.testing.assert_array_equal(back[k], v)


def test_alignment_is_64(tmp_path, rng):
    tensors = {"a": rng.standard_normal(3).astype(np.float32),
               "b": rng.standard_normal(5).astype(np.float32)}
    path = str(tmp_path / "t.rkv")
    export.write_rkv(path, tensors)
    import struct

    raw = open(path, "rb").read()
    (data_offset,) = struct.unpack_from("<Q", raw, 12)
    assert data_offset % 64 == 0


def test_model_tensor_naming_contract(tmp_path):
    """The rust engine depends on these exact names (weights.rs)."""
    p = rwkv.init(TINY, 0)
    t = export.model_tensors(p, TINY, precision="f16")
    for required in [
        "emb", "head", "ln0.scale", "ln_out.bias",
        "b0.ln1.scale", "b0.att.mu_r", "b0.att.decay", "b0.att.first",
        "b0.att.wr.w", "b0.att.wo.w", "b0.att.lnx.scale",
        "b0.ffn.mu_k", "b0.ffn.wr.w", "b0.ffn.wk_t", "b0.ffn.wv",
        "b1.ln2.bias",
    ]:
        assert required in t, required
    # transposed layouts
    assert t["head"].shape == (64, 32)
    assert t["b0.ffn.wk_t"].shape == (int(32 * 3.5), 32)
    # decay precomputed in (0, 1)
    assert (t["b0.att.decay"] > 0).all() and (t["b0.att.decay"] < 1).all()


def test_int8_export_has_scales(tmp_path, monkeypatch):
    monkeypatch.setattr(export, "_MATRIX_MIN", 1)  # tiny test dims
    p = rwkv.init(TINY, 1)
    t = export.model_tensors(p, TINY, precision="int8")
    assert t["head"].dtype == np.int8
    assert "head.scale" in t and t["head.scale"].shape == (64,)
    assert t["b0.ffn.wk_t"].dtype == np.int8
    assert t["b0.ffn.wk_t.scale"].shape == (int(32 * 3.5),)


def test_int8_transposed_quant_consistency(rng, monkeypatch):
    """Quantize-then-transpose must equal per-row scales of the transpose."""
    monkeypatch.setattr(export, "_MATRIX_MIN", 1)
    w = rng.standard_normal((32, 16)).astype(np.float32)
    t = {}
    export._emit(t, "x", w, "int8", transpose=True)
    q, scale = t["x"], t["x.scale"]
    assert q.shape == (16, 32)
    back = q.astype(np.float32) * scale[:, None]
    np.testing.assert_allclose(back, w.T, atol=float(np.abs(w).max() / 100))


def test_q4_packed_round_trip(tmp_path, rng):
    from compile.compress import quant

    w = rng.standard_normal((6, 37)).astype(np.float32)  # ragged + odd
    packed, scale = quant.group_q4(w)
    tensors = {
        "w": export.PackedTensor(export.DTYPES["q4"], w.shape, packed),
        "w.scale": scale,
    }
    path = str(tmp_path / "q.rkv")
    export.write_rkv(path, tensors)
    back = export.read_rkv(path)
    assert isinstance(back["w"], export.PackedTensor)
    assert back["w"].code == export.DTYPES["q4"]
    assert back["w"].shape == (6, 37)
    np.testing.assert_array_equal(back["w"].data, packed)
    assert back["w.scale"].dtype == np.float16
    np.testing.assert_array_equal(back["w.scale"], scale)


def test_q4_export_hybrid_selection(monkeypatch):
    monkeypatch.setattr(export, "_MATRIX_MIN", 1)  # tiny test dims
    p = rwkv.init(TINY, 3)
    t = export.model_tensors(p, TINY, precision="q4")
    # big dense matrices go q4 with f16 per-group scale blocks
    assert isinstance(t["head"], export.PackedTensor)
    assert t["head"].code == export.DTYPES["q4"]
    assert t["head"].shape == (64, 32)
    assert t["head.scale"].dtype == np.float16
    assert t["head.scale"].shape == (64, 1)
    assert isinstance(t["b0.att.wr.w"], export.PackedTensor)
    assert isinstance(t["b0.ffn.wk_t"], export.PackedTensor)
    # ffn.wv takes the affine q4_1 variant with a .min sibling
    assert t["b0.ffn.wv"].code == export.DTYPES["q4_1"]
    assert "b0.ffn.wv.min" in t
    # hybrid recipe: embeddings stay f16
    assert t["emb"].dtype == np.float16


def test_export_model_writes_manifest(tmp_path):
    p = rwkv.init(TINY, 2)
    path = export.export_model(str(tmp_path), "m", p, TINY, "f16")
    assert os.path.exists(path)
    import json

    man = json.load(open(tmp_path / "m.json"))
    assert man["config"]["dim"] == 32
    assert man["runtime"]["hh_p_min"] == 0.95
