"""Compression pipeline correctness: SVD, quantizers, k-means, predictors,
cluster heads."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.common import ModelConfig
from compile.compress import heads, quant, sparsity, svd
from compile.models import rwkv

TINY = ModelConfig(arch="rwkv", variant="tiny", dim=32, layers=2, vocab=64, head_size=8)


# ---------------------------------------------------------------------------
# SVD
# ---------------------------------------------------------------------------


def test_svd_exact_for_lowrank_matrix(rng):
    a = rng.standard_normal((24, 4)).astype(np.float32)
    b = rng.standard_normal((4, 24)).astype(np.float32)
    w = a @ b  # rank 4
    l, r = svd.decompose(w, 4)
    assert svd.reconstruction_error(w, l, r) < 1e-5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_svd_error_decreases_with_rank(seed):
    g = np.random.default_rng(seed)
    w = g.standard_normal((32, 32)).astype(np.float32)
    errs = [svd.reconstruction_error(w, *svd.decompose(w, r)) for r in (2, 8, 16, 32)]
    assert all(errs[i] >= errs[i + 1] - 1e-6 for i in range(len(errs) - 1))
    assert errs[-1] < 1e-4  # full rank reconstructs


def test_decompose_model_structure():
    p = rwkv.init(TINY, 0)
    cfg8 = ModelConfig(arch="rwkv", variant="tiny", dim=32, layers=2, vocab=64,
                       head_size=8, svd_rank_div=4)
    dp = svd.decompose_model(p, cfg8)
    blk = dp["blocks"][0]
    for k in ("wr", "wk", "wv", "wg"):
        assert set(blk["att"][k].keys()) == {"l", "r"}
        assert blk["att"][k]["l"].shape == (32, 8)
    assert "w" in blk["att"]["wo"]  # wo NOT decomposed (paper §3.1)
    assert set(blk["ffn"]["wr"].keys()) == {"l", "r"}


def test_decomposed_model_approximates_dense():
    """With generous rank, the decomposed model's logits are close."""
    p = rwkv.init(TINY, 1)
    cfg2 = ModelConfig(arch="rwkv", variant="tiny", dim=32, layers=2, vocab=64,
                       head_size=8, svd_rank_div=2)
    dp = svd.decompose_model(p, cfg2)
    toks = np.array([[3, 9, 12]], np.int32)
    dense = np.asarray(rwkv.forward(p, TINY, toks))
    low = np.asarray(rwkv.forward(dp, cfg2, toks))
    # rank D/2 keeps most of the spectrum of near-orthogonal inits
    assert np.abs(dense - low).mean() < 0.5 * np.abs(dense).mean() + 1e-3


# ---------------------------------------------------------------------------
# Quantizers
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_int_quant_bounds_and_error(bits, seed):
    g = np.random.default_rng(seed)
    w = g.standard_normal((16, 8)).astype(np.float32)
    q, scale = quant.int_quant(w, bits)
    qmax = (1 << (bits - 1)) - 1
    assert np.abs(q).max() <= qmax
    err = quant.quant_error(w, bits)
    assert err < (0.7 if bits == 2 else 0.3 if bits == 4 else 0.02)


def test_int_quant_error_monotone_in_bits(rng):
    w = rng.standard_normal((32, 16)).astype(np.float32)
    errs = [quant.quant_error(w, b) for b in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]


def test_sign_quant_round_trip(rng):
    w = rng.standard_normal((24, 8)).astype(np.float32)
    packed, scale = quant.sign_quant(w)
    back = quant.sign_dequant(packed, scale, 24)
    assert back.shape == w.shape
    # signs preserved wherever w != 0
    assert np.all(np.sign(back)[w != 0] == np.sign(w)[w != 0])
    # scale is the mean |w| per column
    np.testing.assert_allclose(np.abs(back), np.tile(scale, (24, 1)), rtol=1e-6)


def test_nibble_quant_round_trip(rng):
    for rows in (10, 11):  # even + odd (pad path)
        w = rng.standard_normal((rows, 6)).astype(np.float32)
        packed, scale = quant.nibble_quant(w)
        assert packed.dtype == np.uint8
        assert packed.shape == ((rows + 1) // 2, 6)
        back = quant.nibble_dequant(packed, scale, rows)
        assert back.shape == w.shape
        # 4-bit symmetric: error bounded by scale/2 per element
        assert np.all(np.abs(back - w) <= scale / 2 + 1e-6)


def test_group_q4_round_trip(rng):
    for cols in (32, 40, 37, 5):  # whole, multi-group, ragged, sub-group
        w = rng.standard_normal((7, cols)).astype(np.float32)
        packed, scale = quant.group_q4(w)
        assert packed.dtype == np.uint8 and packed.shape == (7, (cols + 1) // 2)
        assert scale.dtype == np.float16
        assert scale.shape == (7, -(-cols // quant.Q4_GROUP))
        back = quant.group_q4_dequant(packed, scale, cols)
        # symmetric 4-bit: error bounded by half a quantization step
        step = np.repeat(scale.astype(np.float32), quant.Q4_GROUP, axis=1)[:, :cols]
        assert np.all(np.abs(back - w) <= step / 2 + 1e-6)


def test_group_q4_1_round_trip(rng):
    for cols in (32, 40, 37, 5):
        w = rng.standard_normal((7, cols)).astype(np.float32)
        packed, scale, mn = quant.group_q4_1(w)
        assert packed.shape == (7, (cols + 1) // 2)
        assert scale.dtype == np.float16 and mn.dtype == np.float16
        back = quant.group_q4_1_dequant(packed, scale, mn, cols)
        # affine 4-bit: half a step plus the f16 rounding of the offset
        step = np.repeat(scale.astype(np.float32), quant.Q4_GROUP, axis=1)[:, :cols]
        slack = step / 2 + np.abs(w) * 1e-3 + 1e-6
        assert np.all(np.abs(back - w) <= slack)


def test_group_q4_pad_nibbles_are_canonical(rng):
    # odd trailing column: high nibble of the last byte must be 8 for q4
    # (offset-binary zero) and 0 for q4_1 — the rust reader relies on the
    # quantizers being bit-deterministic about bytes it never dequantizes
    w = rng.standard_normal((3, 5)).astype(np.float32)
    packed, _ = quant.group_q4(w)
    assert np.all(packed[:, 2] >> 4 == 8)
    packed1, _, _ = quant.group_q4_1(w)
    assert np.all(packed1[:, 2] >> 4 == 0)


def test_group_q4_1_ragged_group_ignores_padding(rng):
    # every value in the ragged final group is >= 2: zero-padding would
    # drag the group minimum to 0 and corrupt the offset — the quantizer
    # must take min/max over REAL elements only
    w = 2.0 + rng.random((4, 40)).astype(np.float32)
    _, _, mn = quant.group_q4_1(w)
    assert np.all(mn.astype(np.float32) >= 1.9)


def test_group_q4_zero_group_survives(rng):
    w = np.zeros((2, 64), np.float32)
    packed, scale = quant.group_q4(w)
    assert np.all(scale == 0)
    back = quant.group_q4_dequant(packed, scale, 64)
    assert np.all(back == 0)


def test_nibble_more_accurate_than_sign(rng):
    w = rng.standard_normal((64, 32)).astype(np.float32)
    p4, s4 = quant.nibble_quant(w)
    b4 = quant.nibble_dequant(p4, s4, 64)
    p1, s1 = quant.sign_quant(w)
    b1 = quant.sign_dequant(p1, s1, 64)
    e4 = np.linalg.norm(w - b4)
    e1 = np.linalg.norm(w - b1)
    assert e4 < e1


def test_sign_quant_preserves_score_correlation(rng):
    """The 1-bit predictor works because x@W and x@sign(W) correlate."""
    w = rng.standard_normal((64, 128)).astype(np.float32)
    packed, scale = quant.sign_quant(w)
    wsign = quant.sign_dequant(packed, scale, 64)
    x = rng.standard_normal(64).astype(np.float32)
    a, b = x @ w, x @ wsign
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.5, corr


# ---------------------------------------------------------------------------
# K-means + hierarchical head
# ---------------------------------------------------------------------------


def test_kmeans_separates_blobs(rng):
    blobs = np.concatenate([
        rng.normal(0, 0.1, (30, 4)),
        rng.normal(5, 0.1, (30, 4)),
        rng.normal(-5, 0.1, (30, 4)),
    ]).astype(np.float32)
    c, assign = heads.kmeans(blobs, 3, seed=0)
    # each blob maps to exactly one cluster
    for start in (0, 30, 60):
        assert len(set(assign[start : start + 30].tolist())) == 1
    assert len(set(assign.tolist())) == 3


def test_kmeans_assignment_covers_all_points(rng):
    x = rng.standard_normal((100, 8)).astype(np.float32)
    c, assign = heads.kmeans(x, 10, seed=1)
    assert assign.shape == (100,)
    assert assign.min() >= 0 and assign.max() < 10


# ---------------------------------------------------------------------------
# Sparsity predictors
# ---------------------------------------------------------------------------


def test_collect_activations_shapes():
    p = rwkv.init(TINY, 2)
    toks = np.arange(400, dtype=np.int32) % 64
    acts = sparsity.collect_activations(p, TINY, toks, n_samples=128, seqlen=32)
    assert len(acts) == TINY.layers
    assert acts[0]["x"].shape == (128, 32)
    assert acts[0]["mask"].shape == (128, int(32 * 3.5))


def test_predictor_training_beats_random():
    p = rwkv.init(TINY, 3)
    toks = np.arange(800, dtype=np.int32) % 64
    acts = sparsity.collect_activations(p, TINY, toks, n_samples=256, seqlen=32)
    preds = sparsity.init_predictors(TINY)
    trained = sparsity.train_predictors(preds, acts, epochs=20, bsz=128, verbose=False)
    shadows = sparsity.build_shadow(p, bits=1)
    stats = sparsity.ensemble_stats(p, TINY, trained, shadows, acts, t_mlp=0.5, t_quant=0.8)
    for layer_stats in stats["per_layer"]:
        ens = layer_stats["ensemble"]
        # union recall must be >= each member's recall
        assert ens["recall"] >= layer_stats["mlp"]["recall"] - 1e-9
        assert ens["recall"] >= layer_stats["quant"]["recall"] - 1e-9
        # and materially better than chance coverage at this kept rate
        assert ens["recall"] > ens["kept"] * 0.9


def test_ensemble_union_property(rng):
    """max(P_mlp, P_quant) == OR of masks (paper Eq. 5)."""
    a = rng.random((10, 20)) > 0.7
    b = rng.random((10, 20)) > 0.7
    assert np.array_equal(np.maximum(a, b), a | b)
