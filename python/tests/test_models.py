"""L2 model correctness: RWKV step-vs-sequence parity, SVD variants,
transformer shapes, AOT component parity."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import aot
from compile.common import ModelConfig, rwkv_config, transformer_config
from compile.models import rwkv, transformer

TINY = ModelConfig(arch="rwkv", variant="tiny", dim=32, layers=2, vocab=64, head_size=8)


def test_forward_shapes():
    p = rwkv.init(TINY, 0)
    toks = np.array([[1, 2, 3]], np.int32)
    logits = rwkv.forward(p, TINY, toks)
    assert logits.shape == (1, 3, 64)


@pytest.mark.parametrize("svd,enh", [(0, False), (4, False), (4, True)])
def test_step_matches_sequence(svd, enh):
    cfg = ModelConfig(
        arch="rwkv", variant="tiny", dim=32, layers=2, vocab=64, head_size=8,
        svd_rank_div=svd, enhanced_svd=enh,
    )
    p = rwkv.init(cfg, 1)
    toks = np.array([[5, 6, 7, 8]], np.int32)
    seq_logits = np.asarray(rwkv.forward(p, cfg, toks))[0]
    st = rwkv.init_state(cfg)
    for i, t in enumerate(toks[0]):
        hid, st = rwkv.step(p, cfg, p["emb"][t], st, impl="jnp")
        step_logits = np.asarray(rwkv.logits_from_hidden(p, hid))
        np.testing.assert_allclose(step_logits, seq_logits[i], rtol=1e-4, atol=1e-4)


def test_pallas_step_matches_jnp_step():
    p = rwkv.init(TINY, 2)
    st1 = rwkv.init_state(TINY)
    st2 = rwkv.init_state(TINY)
    x = p["emb"][7]
    h1, st1 = rwkv.step(p, TINY, x, st1, impl="jnp")
    h2, st2 = rwkv.step(p, TINY, x, st2, impl="pallas")
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st1["wkv"]), np.asarray(st2["wkv"]), rtol=1e-4, atol=1e-4)


def test_aot_components_match_full_step():
    p = rwkv.init(TINY, 3)
    st = rwkv.init_state(TINY)
    x = p["emb"][11]
    h_full, _ = rwkv.step(p, TINY, x, st, impl="jnp")
    h_comp, _ = aot.run_component_reference(p, TINY, x, st)
    np.testing.assert_allclose(np.asarray(h_full), h_comp, rtol=1e-4, atol=1e-4)


def test_state_propagates_information():
    """Same token, different prior context -> different logits."""
    p = rwkv.init(TINY, 4)
    cfg = TINY
    # at init the residual outputs (wo, ffn.wv) are zero (standard RWKV
    # init); randomize them so block outputs actually flow
    g = np.random.default_rng(0)
    for b in p["blocks"]:
        b["att"]["wo"]["w"] = g.standard_normal(b["att"]["wo"]["w"].shape).astype(np.float32) * 0.1
        b["ffn"]["wv"] = g.standard_normal(b["ffn"]["wv"].shape).astype(np.float32) * 0.1
    a = np.array([[1, 2, 3, 9]], np.int32)
    b = np.array([[4, 5, 6, 9]], np.int32)
    la = np.asarray(rwkv.forward(p, cfg, a))[0, -1]
    lb = np.asarray(rwkv.forward(p, cfg, b))[0, -1]
    assert np.abs(la - lb).max() > 1e-6


def test_svd_param_reduction():
    dense = rwkv.init(rwkv_config("tiny"), 0)
    low = rwkv.init(rwkv_config("tiny", svd_rank_div=8), 0)
    from compile.common import tree_size

    assert tree_size(low) < tree_size(dense)
    gd = rwkv.param_groups(dense, rwkv_config("tiny"))
    gl = rwkv.param_groups(low, rwkv_config("tiny", svd_rank_div=8))
    assert gl["square"] < gd["square"]
    assert gl["non_square"] == gd["non_square"]  # FFN not decomposed


def test_transformer_forward_and_groups():
    cfg = transformer_config("tiny")
    p = transformer.init(cfg, 0)
    toks = np.array([[1, 2, 3, 4]], np.int32)
    logits = transformer.forward(p, cfg, toks)
    assert logits.shape == (1, 4, cfg.vocab)
    g = transformer.param_groups(p, cfg)
    assert g["square"] == 4 * cfg.layers * cfg.dim * cfg.dim


def test_causality():
    """Changing a later token must not affect earlier logits."""
    p = rwkv.init(TINY, 6)
    a = np.array([[1, 2, 3, 4]], np.int32)
    b = np.array([[1, 2, 3, 60]], np.int32)
    la = np.asarray(rwkv.forward(p, TINY, a))
    lb = np.asarray(rwkv.forward(p, TINY, b))
    np.testing.assert_allclose(la[0, :3], lb[0, :3], rtol=1e-5, atol=1e-5)
