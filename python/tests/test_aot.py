"""AOT lowering: HLO text generation + parameter-order manifest."""

import os

import numpy as np
import pytest

from compile import aot
from compile.common import ModelConfig
from compile.models import rwkv

TINY = ModelConfig(arch="rwkv", variant="tiny", dim=32, layers=2, vocab=64, head_size=8)
TINY_SVD = ModelConfig(arch="rwkv", variant="tiny", dim=32, layers=2, vocab=64,
                       head_size=8, svd_rank_div=4)


@pytest.mark.parametrize("cfg", [TINY, TINY_SVD], ids=["dense", "svd"])
def test_lowering_produces_hlo_text(tmp_path, cfg):
    p = rwkv.init(cfg, 0)
    man = aot.lower_model_components(p, cfg, "m", str(tmp_path), impl="pallas")
    for comp in ("timemix", "chanmix", "head"):
        path = tmp_path / man[comp]["path"]
        text = path.read_text()
        assert text.startswith("HloModule"), comp
        assert "parameter" in text
        assert len(man[comp]["params"]) >= 1


def test_weight_name_order_dense():
    p = rwkv.init(TINY, 0)
    names = aot.timemix_weight_names(p["blocks"][0])
    assert names[:2] == ["ln1.scale", "ln1.bias"]
    assert "att.wr.w" in names and "att.wo.w" in names
    cm = aot.chanmix_weight_names(p["blocks"][0])
    assert cm[-2:] == ["ffn.wk_t", "ffn.wv"]


def test_weight_name_order_svd():
    p = rwkv.init(TINY_SVD, 0)
    names = aot.timemix_weight_names(p["blocks"][0])
    assert "att.wr.l" in names and "att.wr.r" in names
    assert "att.wr.w" not in names
    assert "att.wo.w" in names  # wo stays dense


def test_get_block_tensor_resolves_all_names():
    p = rwkv.init(TINY_SVD, 1)
    b = p["blocks"][0]
    for n in aot.timemix_weight_names(b) + aot.chanmix_weight_names(b):
        arr = aot._get_block_tensor(b, n)
        assert arr.size > 0, n
    # wk_t really is the transpose
    wk_t = aot._get_block_tensor(b, "ffn.wk_t")
    np.testing.assert_array_equal(wk_t, np.asarray(b["ffn"]["wk"]).T)


def test_component_parity_with_svd_variant():
    p = rwkv.init(TINY_SVD, 2)
    st = rwkv.init_state(TINY_SVD)
    x = p["emb"][5]
    h_full, _ = rwkv.step(p, TINY_SVD, x, st, impl="jnp")
    h_comp, _ = aot.run_component_reference(p, TINY_SVD, x, st)
    np.testing.assert_allclose(np.asarray(h_full), h_comp, rtol=1e-4, atol=1e-4)
