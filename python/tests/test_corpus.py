"""Synthetic corpus (the Pile/lambada substitution): determinism, Zipfian
long tail, task well-formedness."""

import numpy as np
import pytest

from compile.common import VOCAB_SIZE
from compile.data import corpus


@pytest.fixture(scope="module")
def vc():
    return corpus.build_vocab()


def test_vocab_size_and_specials(vc):
    vocab, classes = vc
    assert len(vocab) == VOCAB_SIZE
    assert vocab.words[corpus.PAD] == "<pad>"
    assert vocab.words[corpus.UNK] == "<unk>"
    assert vocab.words[corpus.BOS] == "<bos>"
    assert vocab.words[corpus.EOS] == "<eos>"
    # no duplicate words
    assert len(set(vocab.words)) == len(vocab.words)


def test_vocab_deterministic():
    v1, _ = corpus.build_vocab()
    v2, _ = corpus.build_vocab()
    assert v1.words == v2.words


def test_training_stream_deterministic(vc):
    vocab, classes = vc
    a = corpus.training_tokens(vocab, classes, 5000, seed=11)
    b = corpus.training_tokens(vocab, classes, 5000, seed=11)
    np.testing.assert_array_equal(a, b)
    c = corpus.training_tokens(vocab, classes, 5000, seed=12)
    assert not np.array_equal(a, c)


def test_stream_in_vocab_and_no_unk(vc):
    vocab, classes = vc
    toks = corpus.training_tokens(vocab, classes, 20000)
    assert toks.min() >= 0 and toks.max() < VOCAB_SIZE
    # the generator should never emit OOV
    assert (toks == corpus.UNK).sum() == 0


def test_long_tail_distribution(vc):
    """Zipfian usage: a small head of tokens covers most of the stream —
    the property the embedding cache (§3.3) exploits."""
    vocab, classes = vc
    toks = corpus.training_tokens(vocab, classes, 50000)
    counts = np.bincount(toks, minlength=VOCAB_SIZE)
    order = np.argsort(-counts)
    top64 = counts[order[:64]].sum() / counts.sum()
    assert top64 > 0.6, f"top-64 coverage {top64:.2f}"
    # and hundreds of tokens are never used (reserved tail)
    assert (counts == 0).sum() > 100


def test_lambada_answer_in_context(vc):
    """The gold word must appear in the distant context (lambada shape)."""
    vocab, classes = vc
    tasks = corpus.make_tasks(vocab, classes, n_per_task=30, seed=5)
    for e in tasks["lambada_syn"]:
        assert e["gold"] in e["ctx"], "answer must be recoverable from context"
        # the answer is not trivially the previous token
        assert e["ctx"][-1] != e["gold"]


def test_choice_tasks_well_formed(vc):
    vocab, classes = vc
    tasks = corpus.make_tasks(vocab, classes, n_per_task=25, seed=6)
    for name in ("cloze_syn", "assoc_syn", "social_syn", "agree_syn"):
        for e in tasks[name]:
            assert 0 <= e["label"] < len(e["choices"])
            assert len(set(tuple(c) for c in e["choices"])) == len(e["choices"]), name


def test_tasks_use_held_out_seed(vc):
    vocab, classes = vc
    t1 = corpus.make_tasks(vocab, classes, n_per_task=10, seed=1234)
    t2 = corpus.make_tasks(vocab, classes, n_per_task=10, seed=1234)
    assert t1["lambada_syn"][0] == t2["lambada_syn"][0]


def test_assoc_affinity_is_learnable(vc):
    """obj->place affinity is consistent across documents (world
    knowledge); the assoc task gold always matches the grammar's map."""
    vocab, classes = vc
    g = corpus.Grammar(vocab, classes, seed=9)
    obj = classes["object"][0]
    assert g.obj_place[obj] == g.obj_place[obj]
    ctx, choices, label = g.task_assoc()
    gold = choices[label][0]
    obj_word = ctx[1]
    assert g.obj_place[obj_word] == gold
