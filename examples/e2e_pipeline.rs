//! End-to-end driver (DESIGN.md "End-to-end validation"): proves all three
//! layers compose on a real small workload.
//!
//! Pipeline exercised:
//!   1. python built the artifacts (`make artifacts`): trained RWKV v5 on
//!      the synthetic corpus, ran SVD/continual-training, trained the
//!      sparsity-predictor ensemble + hierarchical head, exported `.rkv`
//!      checkpoints and AOT HLO components (L2 jax + L1 Pallas).
//!   2. THIS binary (L3) loads vanilla and compressed checkpoints, runs
//!      the XLA backend (HLO via PJRT) against the native backend for a
//!      numerics cross-check, serves batched requests, evaluates the
//!      lambada-style benchmark, and reports the paper's headline metric:
//!      the memory-reduction factor at matched accuracy.
//!
//! Output is the EXPERIMENTS.md "E2E" record.

use std::path::PathBuf;

use anyhow::Result;
use rwkv_lite::config::{Backend, EngineConfig, LoadStrategy};
use rwkv_lite::coordinator::{batcher::BatchPolicy, Coordinator, Request};
use rwkv_lite::engine::sampler::Sampler;
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::evalsuite;
use rwkv_lite::text::Vocab;
use rwkv_lite::util::{fmt_bytes, Stopwatch};

const SIZE: &str = "small";

fn main() -> Result<()> {
    let artifacts = PathBuf::from("artifacts");
    let vanilla_name = format!("rwkv-vanilla-{SIZE}");
    let ours_name = format!("rwkv-ours-{SIZE}");
    if !artifacts.join("models").join(format!("{ours_name}.json")).exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let vocab = Vocab::load(&artifacts.join("data/vocab.json"))?;
    println!("=== RWKV-Lite end-to-end driver ({SIZE}) ===\n");

    // ---- step 1: backend cross-check (L1/L2 HLO vs L3 native kernels) --
    println!("[1/4] backend cross-check (native vs AOT-HLO/PJRT)");
    let greedy = |cfg: EngineConfig| -> Result<Vec<u32>> {
        let mut e = RwkvEngine::load(cfg)?;
        let mut s = e.new_state();
        e.generate(&vocab.encode("the"), 16, &mut Sampler::greedy(), &mut s)
    };
    let native = greedy(EngineConfig::vanilla(&vanilla_name, artifacts.clone()))?;
    let mut xla_cfg = EngineConfig::vanilla(&vanilla_name, artifacts.clone());
    xla_cfg.backend = Backend::Xla;
    let xla = greedy(xla_cfg)?;
    anyhow::ensure!(native == xla, "backend mismatch: {native:?} vs {xla:?}");
    println!("      16-token greedy continuation identical across backends ✓\n");

    // ---- step 2: accuracy at matched tasks -----------------------------
    println!("[2/4] benchmark accuracy (lambada_syn, 100 examples)");
    let tasks = evalsuite::load_tasks(&artifacts.join("data/tasks.json"))?;
    let eval = |cfg: EngineConfig| -> Result<(f64, f64)> {
        let mut e = RwkvEngine::load(cfg)?;
        let r = evalsuite::eval_task(&mut e, &tasks["lambada_syn"], 100)?;
        Ok((r.acc, r.ppl))
    };
    let (acc_v, ppl_v) = eval(EngineConfig::vanilla(&vanilla_name, artifacts.clone()))?;
    let (acc_o, ppl_o) = eval(EngineConfig::all_techniques(&ours_name, artifacts.clone()))?;
    println!("      vanilla: acc {acc_v:.3} ppl {ppl_v:.2}");
    println!("      ours   : acc {acc_o:.3} ppl {ppl_o:.2}  (Δacc {:+.3})\n", acc_o - acc_v);

    // ---- step 3: memory footprint --------------------------------------
    println!("[3/4] peak memory under both loading strategies (32-token generation)");
    let peak = |cfg: EngineConfig, strategy: LoadStrategy| -> Result<u64> {
        let mut cfg = cfg;
        cfg.strategy = strategy;
        let mut e = RwkvEngine::load(cfg)?;
        let mut s = e.new_state();
        e.generate(&vocab.encode("the"), 32, &mut Sampler::new(0.8, 0.95, 3), &mut s)?;
        Ok(e.memory_report().1)
    };
    let pv_full = peak(EngineConfig::vanilla(&vanilla_name, artifacts.clone()), LoadStrategy::Full)?;
    let po_full = peak(EngineConfig::all_techniques(&ours_name, artifacts.clone()), LoadStrategy::Full)?;
    let pv_lw = peak(EngineConfig::vanilla(&vanilla_name, artifacts.clone()), LoadStrategy::Layerwise)?;
    let po_lw = peak(EngineConfig::all_techniques(&ours_name, artifacts.clone()), LoadStrategy::Layerwise)?;
    let rf = pv_full as f64 / po_full as f64;
    let rl = pv_lw as f64 / po_lw as f64;
    println!("      full loading:      vanilla {} -> ours {}   ({rf:.1}x)", fmt_bytes(pv_full), fmt_bytes(po_full));
    println!("      layerwise loading: vanilla {} -> ours {}   ({rl:.1}x)\n", fmt_bytes(pv_lw), fmt_bytes(po_lw));

    // ---- step 4: batched serving ---------------------------------------
    println!("[4/4] batched serving (8 concurrent requests x 24 tokens)");
    let cfg = EngineConfig::all_techniques(&ours_name, artifacts.clone());
    let coordinator = Coordinator::spawn(
        move || RwkvEngine::load(cfg),
        BatchPolicy { max_batch: 8, window_ms: 3 },
    );
    let wall = Stopwatch::start();
    let rxs: Vec<_> = (0..8u64)
        .map(|i| {
            coordinator.submit(Request {
                id: i,
                prompt: vocab.encode("in the end the"),
                max_tokens: 24,
                temperature: 0.8,
                top_p: 0.95,
            })
        })
        .collect();
    let mut total = 0usize;
    for rx in rxs {
        for ev in rx {
            match ev {
                rwkv_lite::coordinator::Event::Done { tokens, .. } => {
                    total += tokens;
                    break;
                }
                rwkv_lite::coordinator::Event::Error { message } => {
                    anyhow::bail!("serving failed: {message}")
                }
                _ => {}
            }
        }
    }
    let secs = wall.elapsed_secs();
    println!(
        "      {total} tokens in {secs:.2}s = {:.1} tok/s aggregate, {} rounds\n",
        total as f64 / secs,
        coordinator.metrics.counter("rounds")
    );

    println!("=== E2E summary (record in EXPERIMENTS.md) ===");
    println!("accuracy  vanilla {acc_v:.3} -> ours {acc_o:.3} (Δ {:+.3})", acc_o - acc_v);
    println!("memory    {rf:.1}x less (full), {rl:.1}x less (layerwise)");
    println!("paper     4x (full), 5x (layerwise) at ~1pp accuracy cost");
    let ok = rf >= 2.0 && (acc_v - acc_o) < 0.08;
    println!("verdict   {}", if ok { "REPRODUCED (shape preserved)" } else { "CHECK RESULTS" });
    std::process::exit(if ok { 0 } else { 2 });
}
