//! Quickstart: load a compressed RWKV-Lite checkpoint and generate text.
//!
//! ```bash
//! make artifacts               # once: trains + compresses + exports
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the public API surface a downstream user touches:
//! [`EngineConfig`] -> [`RwkvEngine`] -> [`Sampler`] -> generate, plus the
//! auditable memory report that is the paper's headline.

use std::path::PathBuf;

use anyhow::Result;
use rwkv_lite::config::EngineConfig;
use rwkv_lite::engine::sampler::Sampler;
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::text::Vocab;
use rwkv_lite::util::fmt_bytes;

fn main() -> Result<()> {
    let artifacts = PathBuf::from("artifacts");
    let vocab = Vocab::load(&artifacts.join("data/vocab.json"))?;

    // The paper's full technique stack: SVD weights come from the
    // checkpoint; sparse FFN + hierarchical head + embedding cache are
    // runtime features toggled here.
    let cfg = EngineConfig::all_techniques("rwkv-ours-small", artifacts.clone());
    let mut engine = RwkvEngine::load(cfg)?;
    println!(
        "loaded {} (dim={} layers={} vocab={})",
        engine.cfg.model, engine.info.dim, engine.info.layers, engine.info.vocab
    );

    let prompt = "the";
    let mut sampler = Sampler::new(0.8, 0.95, 42);
    let mut state = engine.new_state();
    let tokens = engine.generate(&vocab.encode(prompt), 48, &mut sampler, &mut state)?;
    println!("\n{} {}\n", prompt, vocab.decode(&tokens));

    let (resident, peak) = engine.memory_report();
    println!("weights resident: {}   peak: {}", fmt_bytes(resident), fmt_bytes(peak));
    if let Some(cache) = &engine.emb_cache {
        println!(
            "embedding cache: {} rows resident ({} hit rate {:.0}%)",
            cache.len(),
            fmt_bytes(cache.resident_bytes()),
            100.0 * cache.hit_rate()
        );
    }
    if let Some(h) = &engine.hier {
        println!(
            "hierarchical head: {} clusters, mean {:.1} token rows loaded/step",
            h.n_clusters(),
            h.mean_tokens_loaded()
        );
    }
    let spars = engine.sparsity_by_layer();
    println!(
        "FFN rows skipped per layer: {:?}",
        spars.iter().map(|s| format!("{:.0}%", 100.0 * s)).collect::<Vec<_>>()
    );

    // Compare against the vanilla model, full loading:
    let cfg = EngineConfig::vanilla("rwkv-vanilla-small", PathBuf::from("artifacts"));
    let mut vanilla = RwkvEngine::load(cfg)?;
    let mut st = vanilla.new_state();
    vanilla.generate(&vocab.encode(prompt), 8, &mut Sampler::greedy(), &mut st)?;
    let (_, vanilla_peak) = vanilla.memory_report();
    println!(
        "\nvanilla peak: {}  ->  ours peak: {}  ({:.1}x reduction)",
        fmt_bytes(vanilla_peak),
        fmt_bytes(peak),
        vanilla_peak as f64 / peak as f64
    );
    Ok(())
}
