//! Memory-budget explorer: sweep technique combinations and loading
//! strategies for one model, printing the peak-residency ledger — the
//! tool you would use to fit a model onto a 512 MiB-class device.
//!
//! ```bash
//! cargo run --release --example memory_budget -- rwkv-ours-small
//! ```

use std::path::PathBuf;

use anyhow::Result;
use rwkv_lite::config::{EngineConfig, LoadStrategy};
use rwkv_lite::engine::sampler::Sampler;
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::metrics::Group;
use rwkv_lite::util::fmt_bytes;

fn measure(mut cfg: EngineConfig, strategy: LoadStrategy) -> Result<(u64, String)> {
    cfg.strategy = strategy;
    let mut engine = RwkvEngine::load(cfg)?;
    let mut sampler = Sampler::new(0.8, 0.95, 5);
    let mut state = engine.new_state();
    engine.generate(&[2, 100, 200], 32, &mut sampler, &mut state)?;
    let (_, peak) = engine.memory_report();
    let groups = engine.tracker().peak_by_group();
    let detail = [Group::Emb, Group::TimeMix, Group::ChanMix, Group::Head]
        .iter()
        .map(|g| format!("{}={}", g.name(), fmt_bytes(*groups.get(g).unwrap_or(&0))))
        .collect::<Vec<_>>()
        .join(" ");
    Ok((peak, detail))
}

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "rwkv-ours-small".into());
    let artifacts = PathBuf::from("artifacts");
    println!("memory budget sweep for {model}\n");
    println!(
        "{:<34} {:<10} {:>12}   breakdown",
        "techniques", "strategy", "peak"
    );

    let combos: [(&str, Box<dyn Fn() -> EngineConfig>); 5] = [
        ("none (vanilla runtime)", Box::new({
            let (m, a) = (model.clone(), artifacts.clone());
            move || EngineConfig::vanilla(&m, a.clone())
        })),
        ("sparse FFN only", Box::new({
            let (m, a) = (model.clone(), artifacts.clone());
            move || {
                let mut c = EngineConfig::vanilla(&m, a.clone());
                c.sparse_ffn = true;
                c
            }
        })),
        ("hier head only", Box::new({
            let (m, a) = (model.clone(), artifacts.clone());
            move || {
                let mut c = EngineConfig::vanilla(&m, a.clone());
                c.hier_head = true;
                c
            }
        })),
        ("emb cache only", Box::new({
            let (m, a) = (model.clone(), artifacts.clone());
            move || {
                let mut c = EngineConfig::vanilla(&m, a.clone());
                c.emb_cache = true;
                c
            }
        })),
        ("all (paper stack)", Box::new({
            let (m, a) = (model.clone(), artifacts.clone());
            move || EngineConfig::all_techniques(&m, a.clone())
        })),
    ];

    for (label, mk) in &combos {
        for strategy in [LoadStrategy::Full, LoadStrategy::Layerwise] {
            match measure(mk(), strategy) {
                Ok((peak, detail)) => println!(
                    "{:<34} {:<10} {:>12}   {}",
                    label,
                    strategy.name(),
                    fmt_bytes(peak),
                    detail
                ),
                Err(e) => println!("{:<34} {:<10}   unavailable: {e}", label, strategy.name()),
            }
        }
    }
    println!("\n(peak = high-water mark of tracked weight residency, incl. transient rows)");
    Ok(())
}
