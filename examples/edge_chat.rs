//! Edge serving demo: start the coordinator + TCP server in-process, fire
//! a wave of concurrent client requests, and report latency/throughput —
//! the serving-side end-to-end of the paper's deployment story (Figure 1's
//! wearable demo, as a reproducible benchmark).
//!
//! ```bash
//! cargo run --release --example edge_chat -- rwkv-ours-small 8
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;
use rwkv_lite::config::EngineConfig;
use rwkv_lite::coordinator::{batcher::BatchPolicy, Coordinator};
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::server::{Client, ServeOptions, Server};
use rwkv_lite::text::Vocab;
use rwkv_lite::util::{percentile, Stopwatch};

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "rwkv-ours-small".into());
    let n_clients: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(8);
    let artifacts = PathBuf::from("artifacts");
    let vocab = Vocab::load(&artifacts.join("data/vocab.json"))?;

    let cfg = EngineConfig::all_techniques(&model, artifacts.clone());
    let coordinator = Coordinator::spawn(
        move || RwkvEngine::load(cfg),
        BatchPolicy { max_batch: n_clients.max(4), window_ms: 3 },
    );
    let server = Arc::new(Server::new(coordinator, vocab));
    let addr = "127.0.0.1:17474";
    {
        let s = Arc::clone(&server);
        let opts = ServeOptions { max_total_conns: Some(n_clients), ..ServeOptions::default() };
        std::thread::spawn(move || s.serve(addr, opts));
    }
    std::thread::sleep(std::time::Duration::from_millis(200));

    println!("firing {n_clients} concurrent chat requests at {addr} (model {model})\n");
    let prompts = ["the", "in the end the", "at the", "finally"];
    let wall = Stopwatch::start();
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let prompt = prompts[i % prompts.len()].to_string();
            std::thread::spawn(move || -> Result<(f64, usize, String)> {
                let mut client = Client::connect(addr)?;
                let t = Stopwatch::start();
                let c = client.complete(&prompt, 24, 0.8)?;
                Ok((t.elapsed_secs(), c.tokens, c.text))
            })
        })
        .collect();
    let mut latencies = Vec::new();
    let mut total_tokens = 0usize;
    for (i, h) in handles.into_iter().enumerate() {
        let (secs, tokens, text) = h.join().unwrap()?;
        println!("client {i}: {tokens} tokens in {secs:.2}s   \"{}\"", truncate(&text, 60));
        latencies.push(secs);
        total_tokens += tokens;
    }
    let wall_secs = wall.elapsed_secs();
    println!("\n== serving summary ==");
    println!("wall time            {:.2}s", wall_secs);
    println!("aggregate throughput {:.1} tok/s", total_tokens as f64 / wall_secs);
    println!("latency p50 / p95    {:.2}s / {:.2}s",
        percentile(&latencies, 50.0), percentile(&latencies, 95.0));
    println!("\ncoordinator metrics:\n{}", server.coordinator.metrics.report());
    Ok(())
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n { s.to_string() } else { format!("{}…", &s[..n]) }
}
